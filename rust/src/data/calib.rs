//! Real-data calibration sets for activation-quant moment folding and
//! frontier sensitivity measurement (`--data DIR` on `uniq infer`,
//! `uniq serve` and `uniq frontier`).
//!
//! A calibration directory holds unlabelled image tensors in either of
//! two formats, loaded in sorted filename order so the set (and its
//! content hash) is deterministic:
//!
//! * **raw f32** (`.f32`, `.bin`, `.raw`): little-endian f32, any whole
//!   number of `[h, w, c]` images per file;
//! * **npy** (`.npy`): numpy v1/v2 headers, C-order `<f4` only, shape
//!   `[h,w,c]`, `[n,h,w,c]`, `[image_len]` or `[n, image_len]`.
//!
//! Anything else fails **loudly** with a typed [`CalibError`] naming
//! the offending file — calibrating activation statistics on garbage
//! (wrong geometry, truncated file, NaN pixels) would silently poison
//! every table exported from it. Files with other extensions are
//! skipped (a README can live next to the tensors), but a directory
//! with no loadable tensor at all is an error, not an empty set.
//!
//! The loader also fingerprints what it read: an FNV-1a-64 hash over
//! every file's name and bytes, recorded (with source path, sample
//! count and UTC timestamp) in the optional `calibration` provenance
//! section of `frozen.json` (`infer::codebook::CalibProvenance`), so a
//! frozen model can always answer "what was this calibrated on?".

use std::fmt;
use std::path::{Path, PathBuf};

/// Typed calibration-load failure; every variant that concerns a file
/// names it.
#[derive(Debug)]
pub enum CalibError {
    /// directory missing or unreadable
    Dir { dir: PathBuf, err: std::io::Error },
    /// no `.npy` / `.f32` / `.bin` / `.raw` file in the directory
    Empty { dir: PathBuf },
    /// file unreadable
    Io { file: PathBuf, err: std::io::Error },
    /// raw-f32 file is not a whole number of images
    BadLength {
        file: PathBuf,
        floats: usize,
        image_len: usize,
    },
    /// npy header unparsable or an unsupported dtype/order
    BadNpy { file: PathBuf, reason: String },
    /// npy shape does not match the model's input geometry
    BadShape {
        file: PathBuf,
        got: Vec<usize>,
        want: Vec<usize>,
    },
    /// a NaN/∞ pixel: moment folding would propagate it into μ, σ
    NonFinite { file: PathBuf, index: usize },
}

impl fmt::Display for CalibError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CalibError::Dir { dir, err } => {
                write!(f, "calibration dir {}: {err}", dir.display())
            }
            CalibError::Empty { dir } => write!(
                f,
                "calibration dir {} holds no .npy/.f32/.bin/.raw tensor \
                 files",
                dir.display()
            ),
            CalibError::Io { file, err } => {
                write!(f, "reading {}: {err}", file.display())
            }
            CalibError::BadLength { file, floats, image_len } => write!(
                f,
                "{}: {floats} floats is not a positive whole number of \
                 {image_len}-float images",
                file.display()
            ),
            CalibError::BadNpy { file, reason } => {
                write!(f, "{}: {reason}", file.display())
            }
            CalibError::BadShape { file, got, want } => write!(
                f,
                "{}: tensor shape {got:?} does not match the model \
                 input {want:?} (accepted: [h,w,c], [n,h,w,c], \
                 [image_len] or [n,image_len])",
                file.display()
            ),
            CalibError::NonFinite { file, index } => write!(
                f,
                "{}: non-finite value at flat index {index} — refusing \
                 to calibrate activation statistics on it",
                file.display()
            ),
        }
    }
}

impl std::error::Error for CalibError {}

/// A loaded calibration set: `n` images of `image` shape, flattened
/// NHWC, plus the provenance ingredients.
#[derive(Debug, Clone)]
pub struct CalibSet {
    pub images: Vec<f32>,
    pub n: usize,
    /// image shape `[h, w, c]` the set was validated against
    pub image: Vec<usize>,
    /// `(file name, images contributed)` in load (sorted) order
    pub files: Vec<(String, usize)>,
    /// FNV-1a-64 over every file's name + raw bytes, hex
    pub content_hash: String,
}

/// Load every tensor file under `dir`, validating each against the
/// model input shape `image` (`[h, w, c]`). See the module docs for
/// the accepted formats and the rejection contract.
pub fn load_dir(dir: &Path, image: &[usize]) -> Result<CalibSet, CalibError> {
    let image_len: usize = image.iter().product();
    let mut names: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|err| CalibError::Dir { dir: dir.to_path_buf(), err })?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            matches!(
                p.extension().and_then(|e| e.to_str()),
                Some("npy") | Some("f32") | Some("bin") | Some("raw")
            )
        })
        .collect();
    names.sort();
    if names.is_empty() {
        return Err(CalibError::Empty { dir: dir.to_path_buf() });
    }
    let mut images = Vec::new();
    let mut files = Vec::new();
    let mut hash = Fnv1a::new();
    for path in &names {
        let bytes = std::fs::read(path)
            .map_err(|err| CalibError::Io { file: path.clone(), err })?;
        hash.update(path.file_name().unwrap_or_default().as_encoded_bytes());
        hash.update(&bytes);
        let vals = match path.extension().and_then(|e| e.to_str()) {
            Some("npy") => parse_npy(path, &bytes, image)?,
            _ => parse_raw_f32(path, &bytes, image_len)?,
        };
        if let Some(i) = vals.iter().position(|v| !v.is_finite()) {
            return Err(CalibError::NonFinite {
                file: path.clone(),
                index: i,
            });
        }
        let file_n = vals.len() / image_len;
        files.push((
            path.file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default(),
            file_n,
        ));
        images.extend_from_slice(&vals);
    }
    let n = images.len() / image_len;
    Ok(CalibSet {
        images,
        n,
        image: image.to_vec(),
        files,
        content_hash: hash.hex(),
    })
}

/// Raw little-endian f32: must be a positive whole number of images.
fn parse_raw_f32(
    file: &Path,
    bytes: &[u8],
    image_len: usize,
) -> Result<Vec<f32>, CalibError> {
    let floats = bytes.len() / 4;
    if bytes.len() % 4 != 0
        || floats == 0
        || image_len == 0
        || floats % image_len != 0
    {
        return Err(CalibError::BadLength {
            file: file.to_path_buf(),
            floats,
            image_len,
        });
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// Minimal npy reader: v1/v2 headers, C-order `<f4` data only.
fn parse_npy(
    file: &Path,
    bytes: &[u8],
    image: &[usize],
) -> Result<Vec<f32>, CalibError> {
    let bad = |reason: &str| CalibError::BadNpy {
        file: file.to_path_buf(),
        reason: reason.to_string(),
    };
    if bytes.len() < 10 || &bytes[0..6] != b"\x93NUMPY" {
        return Err(bad("not an npy file (bad magic)"));
    }
    let major = bytes[6];
    let (header_len, data_start) = match major {
        1 => (
            u16::from_le_bytes([bytes[8], bytes[9]]) as usize,
            10usize,
        ),
        2 | 3 => {
            if bytes.len() < 12 {
                return Err(bad("truncated v2 header"));
            }
            (
                u32::from_le_bytes([
                    bytes[8], bytes[9], bytes[10], bytes[11],
                ]) as usize,
                12usize,
            )
        }
        _ => return Err(bad("unsupported npy major version")),
    };
    let header_end = data_start
        .checked_add(header_len)
        .filter(|&e| e <= bytes.len())
        .ok_or_else(|| bad("header overruns file"))?;
    let header = std::str::from_utf8(&bytes[data_start..header_end])
        .map_err(|_| bad("header is not ascii"))?;
    if !(header.contains("'<f4'") || header.contains("\"<f4\"")) {
        return Err(bad("dtype is not little-endian f32 ('<f4')"));
    }
    if header.contains("'fortran_order': True") {
        return Err(bad("fortran-order arrays are not supported"));
    }
    let shape = parse_npy_shape(header).ok_or_else(|| {
        bad("could not parse 'shape' from the npy header")
    })?;
    // geometry check: per-image dims must match the model input
    let image_len: usize = image.iter().product();
    let per_image_ok = shape.as_slice() == image
        || shape.as_slice() == [image_len]
        || (shape.len() == image.len() + 1 && shape[1..] == *image)
        || (shape.len() == 2 && shape[1] == image_len);
    if !per_image_ok || shape.iter().product::<usize>() == 0 {
        return Err(CalibError::BadShape {
            file: file.to_path_buf(),
            got: shape,
            want: image.to_vec(),
        });
    }
    let n_vals: usize = shape.iter().product();
    let data = &bytes[header_end..];
    if data.len() != n_vals * 4 {
        return Err(bad(&format!(
            "payload is {} bytes, shape {shape:?} needs {}",
            data.len(),
            n_vals * 4
        )));
    }
    Ok(data
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// Extract the `'shape': (…)` tuple from an npy header dict.
fn parse_npy_shape(header: &str) -> Option<Vec<usize>> {
    let at = header.find("'shape'")?;
    let rest = &header[at..];
    let open = rest.find('(')?;
    let close = rest[open..].find(')')? + open;
    let inner = &rest[open + 1..close];
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue; // trailing comma of a 1-tuple
        }
        out.push(part.parse::<usize>().ok()?);
    }
    if out.is_empty() {
        return None; // 0-d scalar: not an image tensor
    }
    Some(out)
}

/// FNV-1a 64-bit — the calibration-set fingerprint. Not cryptographic;
/// it detects "different files" for provenance, nothing more.
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// One-shot FNV-1a-64 over a byte buffer — the same fingerprint
/// [`load_dir`] computes per directory, for callers that synthesize
/// their calibration set in memory (synthetic provenance).
pub fn fnv1a_hex(bytes: &[u8]) -> String {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.hex()
}

/// Current UTC wall clock as ISO-8601 (`2026-08-08T12:34:56Z`) — the
/// provenance timestamp. No chrono in the vendor set; see
/// [`unix_to_iso`].
pub fn utc_now_iso() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    unix_to_iso(secs)
}

/// Unix seconds → ISO-8601 UTC, via the days-to-civil algorithm
/// (proleptic Gregorian; exact for any u64 the clock can produce).
pub fn unix_to_iso(secs: u64) -> String {
    let days = (secs / 86_400) as i64;
    let rem = secs % 86_400;
    let (hh, mm, ss) = (rem / 3600, (rem / 60) % 60, rem % 60);
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = yoe + era * 400 + if m <= 2 { 1 } else { 0 };
    format!("{y:04}-{m:02}-{d:02}T{hh:02}:{mm:02}:{ss:02}Z")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("uniq_calib_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_raw(dir: &Path, name: &str, vals: &[f32]) {
        let mut b = Vec::new();
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(dir.join(name), b).unwrap();
    }

    fn npy_bytes(shape: &[usize], vals: &[f32]) -> Vec<u8> {
        let shape_s = match shape.len() {
            1 => format!("({},)", shape[0]),
            _ => format!(
                "({})",
                shape
                    .iter()
                    .map(|d| d.to_string())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        };
        let mut header = format!(
            "{{'descr': '<f4', 'fortran_order': False, 'shape': \
             {shape_s}, }}"
        );
        // pad so 10 + len(header) is a multiple of 64, newline-terminated
        while (10 + header.len() + 1) % 64 != 0 {
            header.push(' ');
        }
        header.push('\n');
        let mut b = Vec::new();
        b.extend_from_slice(b"\x93NUMPY\x01\x00");
        b.extend_from_slice(&(header.len() as u16).to_le_bytes());
        b.extend_from_slice(header.as_bytes());
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b
    }

    #[test]
    fn raw_and_npy_load_in_sorted_order() {
        let d = tmp("ok");
        let img = [2usize, 2, 1];
        write_raw(&d, "b.f32", &[4.0, 5.0, 6.0, 7.0]);
        std::fs::write(
            d.join("a.npy"),
            npy_bytes(&[1, 2, 2, 1], &[0.0, 1.0, 2.0, 3.0]),
        )
        .unwrap();
        std::fs::write(d.join("README.md"), "notes").unwrap();
        let set = load_dir(&d, &img).unwrap();
        assert_eq!(set.n, 2);
        assert_eq!(
            set.images,
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]
        );
        assert_eq!(
            set.files,
            vec![("a.npy".to_string(), 1), ("b.f32".to_string(), 1)]
        );
        // deterministic fingerprint: same files, same hash
        let again = load_dir(&d, &img).unwrap();
        assert_eq!(set.content_hash, again.content_hash);
        assert_eq!(set.content_hash.len(), 16);
    }

    #[test]
    fn npy_shape_variants_accepted() {
        let d = tmp("shapes");
        let img = [2usize, 2, 1];
        std::fs::write(
            d.join("hwc.npy"),
            npy_bytes(&[2, 2, 1], &[0.0; 4]),
        )
        .unwrap();
        std::fs::write(d.join("flat.npy"), npy_bytes(&[4], &[0.0; 4]))
            .unwrap();
        std::fs::write(
            d.join("nflat.npy"),
            npy_bytes(&[3, 4], &[0.5; 12]),
        )
        .unwrap();
        let set = load_dir(&d, &img).unwrap();
        assert_eq!(set.n, 5);
    }

    #[test]
    fn empty_dir_is_a_typed_error_naming_the_dir() {
        let d = tmp("empty");
        std::fs::write(d.join("notes.txt"), "no tensors here").unwrap();
        let err = load_dir(&d, &[2, 2, 1]).unwrap_err();
        assert!(matches!(err, CalibError::Empty { .. }), "{err}");
        assert!(err.to_string().contains("uniq_calib_empty"), "{err}");
    }

    #[test]
    fn ragged_raw_file_names_the_file() {
        let d = tmp("ragged");
        write_raw(&d, "good.f32", &[0.0; 4]);
        write_raw(&d, "short.f32", &[1.0, 2.0, 3.0]);
        let err = load_dir(&d, &[2, 2, 1]).unwrap_err();
        assert!(
            matches!(err, CalibError::BadLength { floats: 3, .. }),
            "{err}"
        );
        assert!(err.to_string().contains("short.f32"), "{err}");
    }

    #[test]
    fn wrong_npy_shape_names_the_file() {
        let d = tmp("shape");
        std::fs::write(
            d.join("wrong.npy"),
            npy_bytes(&[3, 3, 1], &[0.0; 9]),
        )
        .unwrap();
        let err = load_dir(&d, &[2, 2, 1]).unwrap_err();
        match &err {
            CalibError::BadShape { got, want, .. } => {
                assert_eq!(got, &vec![3, 3, 1]);
                assert_eq!(want, &vec![2, 2, 1]);
            }
            other => panic!("wrong variant: {other}"),
        }
        assert!(err.to_string().contains("wrong.npy"), "{err}");
    }

    #[test]
    fn non_finite_pixels_rejected() {
        let d = tmp("nan");
        write_raw(&d, "bad.f32", &[0.0, f32::NAN, 1.0, 2.0]);
        let err = load_dir(&d, &[2, 2, 1]).unwrap_err();
        assert!(
            matches!(err, CalibError::NonFinite { index: 1, .. }),
            "{err}"
        );
        assert!(err.to_string().contains("bad.f32"), "{err}");
    }

    #[test]
    fn npy_rejects_wrong_dtype_and_truncation() {
        let d = tmp("dtype");
        let mut b = npy_bytes(&[2, 2, 1], &[0.0; 4]);
        // corrupt the dtype in place
        let pos = b.windows(4).position(|w| w == b"<f4'").unwrap();
        b[pos..pos + 3].copy_from_slice(b"<f8");
        std::fs::write(d.join("f64.npy"), &b).unwrap();
        let err = load_dir(&d, &[2, 2, 1]).unwrap_err();
        assert!(matches!(err, CalibError::BadNpy { .. }), "{err}");

        let d2 = tmp("trunc");
        let mut t = npy_bytes(&[2, 2, 1], &[0.0; 4]);
        t.truncate(t.len() - 5);
        std::fs::write(d2.join("cut.npy"), &t).unwrap();
        let err = load_dir(&d2, &[2, 2, 1]).unwrap_err();
        assert!(err.to_string().contains("cut.npy"), "{err}");
    }

    #[test]
    fn iso_timestamps_pinned() {
        assert_eq!(unix_to_iso(0), "1970-01-01T00:00:00Z");
        assert_eq!(unix_to_iso(1_000_000_000), "2001-09-09T01:46:40Z");
        assert_eq!(unix_to_iso(1_767_225_599), "2025-12-31T23:59:59Z");
        let now = utc_now_iso();
        assert_eq!(now.len(), 20);
        assert!(now.ends_with('Z'));
    }
}
