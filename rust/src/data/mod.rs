//! Data pipeline: synthetic CIFAR-like dataset, CIFAR binary loader,
//! raw-f32/npy calibration-set loader, augmentation, shuffled batching
//! and a double-buffered prefetcher.

pub mod augment;
pub mod batcher;
pub mod calib;
pub mod cifar;
pub mod synth;

pub use batcher::{Batch, Batcher};
pub use calib::{CalibError, CalibSet};
pub use synth::SynthDataset;

/// An in-memory labelled image dataset, NHWC f32.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn image_len(&self) -> usize {
        self.height * self.width * self.channels
    }

    pub fn image(&self, i: usize) -> &[f32] {
        let l = self.image_len();
        &self.images[i * l..(i + 1) * l]
    }

    /// Split off the last `n_val` examples as a validation set.
    pub fn split(mut self, n_val: usize) -> (Dataset, Dataset) {
        assert!(n_val < self.n);
        let n_train = self.n - n_val;
        let l = self.image_len();
        let val_images = self.images.split_off(n_train * l);
        let val_labels = self.labels.split_off(n_train);
        let val = Dataset {
            images: val_images,
            labels: val_labels,
            n: n_val,
            ..self
        };
        self.n = n_train;
        (self, val)
    }
}
