//! UNIQ — Uniform Noise Injection for Non-Uniform Quantization of Neural
//! Networks (Baskin et al., 2018): a three-layer reproduction.
//!
//! * L3 (this crate): coordinator — gradual-quantization scheduling,
//!   training loop, host-side exact quantizers, data pipeline, BOPs
//!   analyzer, experiment harnesses.
//! * L2/L1 (python/compile, build-time only): JAX model fwd/bwd with the
//!   UNIQ transform, Pallas kernels; AOT-lowered to `artifacts/*.hlo.txt`
//!   and executed here through the PJRT C API (`runtime`).
//! * `infer`: native LUT inference engine — frozen codebook models
//!   (bit-packed indices + k-entry codebooks) executed and served
//!   host-side with batched workers; no PJRT on the request path.
//! * `train`: native training backend — pure-Rust forward/backward with
//!   the UNIQ noise transform behind the `runtime::Backend` trait, used
//!   automatically when the PJRT backend is unavailable.
//!
//! See DESIGN.md for the system inventory and experiment index.

pub mod bops;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod infer;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod train;
pub mod util;
