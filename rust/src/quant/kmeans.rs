//! k-means (Lloyd-Max) quantizer — the ℓ₂-optimal baseline (paper §3.1).
//!
//! 1-D Lloyd iterations: levels ← bin centroids, thresholds ← level
//! midpoints. NP-hard in general; this is the standard heuristic the
//! paper references (Lloyd 1982). Also provides `fit_gaussian`, the
//! pre-calculated N(0,1) table the paper's ablation uses (§4.3), verified
//! against the python golden.

use super::{Quantizer, QuantizerFit};
use crate::stats::norm_icdf;

#[derive(Debug, Clone, Copy)]
pub struct KMeans {
    pub iters: usize,
}

impl Default for KMeans {
    fn default() -> Self {
        KMeans { iters: 100 }
    }
}

impl KMeans {
    /// Lloyd-Max on the *standard normal density* (grid-approximated),
    /// giving the distribution-matched table for weights ~ N(μ, σ²):
    /// scale levels by σ and shift by μ at use site.
    pub fn fit_gaussian(k: usize, iters: usize) -> Quantizer {
        let n = 20_001;
        let xs: Vec<f64> =
            (0..n).map(|i| -6.0 + 12.0 * i as f64 / (n - 1) as f64).collect();
        let pdf: Vec<f64> =
            xs.iter().map(|&x| (-0.5 * x * x).exp()).collect();
        let mut levels: Vec<f64> = (0..k)
            .map(|i| norm_icdf((i as f64 + 0.5) / k as f64))
            .collect();
        for _ in 0..iters {
            let thresh: Vec<f64> = levels
                .windows(2)
                .map(|w| 0.5 * (w[0] + w[1]))
                .collect();
            let mut num = vec![0.0f64; k];
            let mut den = vec![0.0f64; k];
            let mut bin = 0usize;
            for (i, &x) in xs.iter().enumerate() {
                while bin < thresh.len() && x >= thresh[bin] {
                    bin += 1;
                }
                num[bin] += x * pdf[i];
                den[bin] += pdf[i];
            }
            let mut moved = 0.0f64;
            for i in 0..k {
                if den[i] > 0.0 {
                    let c = num[i] / den[i];
                    moved = moved.max((c - levels[i]).abs());
                    levels[i] = c;
                }
            }
            if moved < 1e-12 {
                break;
            }
        }
        let thresholds = levels
            .windows(2)
            .map(|w| (0.5 * (w[0] + w[1])) as f32)
            .collect();
        Quantizer {
            thresholds,
            levels: levels.into_iter().map(|v| v as f32).collect(),
        }
    }
}

impl QuantizerFit for KMeans {
    fn fit(&self, xs: &[f32], k: usize) -> Quantizer {
        assert!(k >= 2 && !xs.is_empty());
        let mut sorted: Vec<f64> =
            xs.iter().map(|&v| v as f64).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // init at k-quantile medians (good + deterministic)
        let n = sorted.len();
        let mut levels: Vec<f64> = (0..k)
            .map(|i| {
                let idx = ((i as f64 + 0.5) / k as f64 * n as f64) as usize;
                sorted[idx.min(n - 1)]
            })
            .collect();
        // prefix sums for O(1) range means
        let mut prefix = vec![0.0f64; n + 1];
        for i in 0..n {
            prefix[i + 1] = prefix[i] + sorted[i];
        }
        for _ in 0..self.iters {
            let thresh: Vec<f64> =
                levels.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
            let mut moved = 0.0f64;
            let mut start = 0usize;
            for i in 0..k {
                let end = if i < thresh.len() {
                    sorted.partition_point(|&v| v < thresh[i])
                } else {
                    n
                };
                if end > start {
                    let c = (prefix[end] - prefix[start])
                        / (end - start) as f64;
                    moved = moved.max((c - levels[i]).abs());
                    levels[i] = c;
                }
                start = end;
            }
            if moved < 1e-10 {
                break;
            }
        }
        let thresholds = levels
            .windows(2)
            .map(|w| (0.5 * (w[0] + w[1])) as f32)
            .collect();
        Quantizer {
            thresholds,
            levels: levels.into_iter().map(|v| v as f32).collect(),
        }
    }

    fn name(&self) -> &'static str {
        "k-means (Lloyd-Max)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{KQuantileEmpirical, QuantizerFit, Uniform};
    use crate::util::prop::prop;

    #[test]
    fn lloyd_is_l2_optimal_among_our_quantizers() {
        // the defining property: lowest MSE of the three families
        prop(15, 301, |g| {
            let n = g.usize_in(500, 2000);
            let xs = g.normal_vec(n, 0.0, 1.0);
            let k = *[4usize, 8].get(g.usize_in(0, 1)).unwrap();
            let km = KMeans::default().fit(&xs, k).mse(&xs);
            let kq = KQuantileEmpirical.fit(&xs, k).mse(&xs);
            let un = Uniform.fit(&xs, k).mse(&xs);
            assert!(km <= kq * 1.001, "kmeans {km} vs kquantile {kq}");
            assert!(km <= un * 1.001, "kmeans {km} vs uniform {un}");
        });
    }

    #[test]
    fn gaussian_table_symmetric() {
        let q = KMeans::fit_gaussian(8, 500);
        for i in 0..4 {
            assert!(
                (q.levels[i] + q.levels[7 - i]).abs() < 2e-3,
                "{:?}",
                q.levels
            );
        }
    }

    #[test]
    fn gaussian_k2_matches_analytic() {
        // optimal 2-level quantizer for N(0,1): levels at ±sqrt(2/π)
        let q = KMeans::fit_gaussian(2, 500);
        let want = (2.0f64 / std::f64::consts::PI).sqrt() as f32;
        assert!((q.levels[1] - want).abs() < 1e-3, "{:?}", q.levels);
    }

    #[test]
    fn clusters_separate_clear_modes() {
        let mut xs = vec![];
        for i in 0..100 {
            xs.push(-5.0 + 0.01 * i as f32);
            xs.push(5.0 + 0.01 * i as f32);
        }
        let q = KMeans::default().fit(&xs, 2);
        assert!(q.levels[0] < 0.0 && q.levels[1] > 0.0);
        assert!((q.levels[0] + 5.0).abs() < 0.6);
    }

    #[test]
    fn mse_never_increases_with_k() {
        let mut g = crate::util::prop::Gen {
            rng: crate::util::rng::Rng::new(42),
        };
        let xs = g.normal_vec(1000, 0.3, 1.2);
        let mut prev = f64::INFINITY;
        for k in [2usize, 4, 8, 16] {
            let mse = KMeans::default().fit(&xs, k).mse(&xs);
            assert!(mse <= prev * 1.001, "k={k}: {mse} > {prev}");
            prev = mse;
        }
    }
}
