//! Host-side exact quantizers (paper §3.1).
//!
//! These run in the coordinator when a layer is *frozen* during the
//! gradual schedule and at inference time; the in-graph Pallas kernels
//! only emulate them with noise during training. Parity between the two
//! is asserted against the python-generated golden vectors.
//!
//! Implemented quantizers (all used by the Table 3 ablation):
//!   * `KQuantileGauss` — the paper's k-quantile with a Gaussian fit
//!     (thresholds `F⁻¹(i/k)`, levels = bin medians `F⁻¹((i-½)/k)`).
//!   * `KQuantileEmpirical` — same, with empirical quantiles/medians.
//!   * `Uniform` — equal-width bins on `[-3σ, 3σ]`, midpoint levels.
//!   * `KMeans` — Lloyd-Max (ℓ₂-optimal) quantizer.
//!   * `PowerCompand` — uniform grid in the power-companded domain
//!     `sign(x)·|x|^alpha`, alpha grid-fit per layer (PowerQuant-style).

pub mod kmeans;
pub mod kquantile;
pub mod power;
pub mod uniform;

pub use kmeans::KMeans;
pub use kquantile::{KQuantileEmpirical, KQuantileGauss};
pub use power::PowerCompand;
pub use uniform::Uniform;

/// A fitted scalar quantizer: a set of increasing thresholds partitioning
/// the line into `levels.len()` bins, and one representation level per bin.
#[derive(Debug, Clone)]
pub struct Quantizer {
    /// len k-1 interior thresholds, strictly increasing.
    pub thresholds: Vec<f32>,
    /// len k representation levels.
    pub levels: Vec<f32>,
}

impl Quantizer {
    pub fn k(&self) -> usize {
        self.levels.len()
    }

    /// Bin index of `x` (levels[bin] is its representation).
    ///
    /// Total on all f32 inputs: ±∞ land in the outermost bins, and NaN —
    /// for which every threshold comparison is false, so the binary
    /// search would silently drift to bin 0 — is pinned to the central
    /// bin. The LUT export path (`infer::codebook`) relies on `bin`
    /// returning a valid index for anything a checkpoint may contain.
    pub fn bin(&self, x: f32) -> usize {
        bin_total(&self.thresholds, self.levels.len(), x)
    }

    pub fn quantize_one(&self, x: f32) -> f32 {
        self.levels[self.bin(x)]
    }

    /// Quantize in place (the freeze path of the coordinator).
    pub fn quantize(&self, xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = self.quantize_one(*x);
        }
    }

    /// Mean squared quantization error over `xs`.
    pub fn mse(&self, xs: &[f32]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter()
            .map(|&x| {
                let e = (x - self.quantize_one(x)) as f64;
                e * e
            })
            .sum::<f64>()
            / xs.len() as f64
    }

    /// Thresholds mapped into the uniformized domain of N(mu, sigma) —
    /// what the generic-noise training path consumes (padded to kmax+1
    /// with leading 0 / trailing 1).
    pub fn uniformized_thresholds(
        &self,
        mu: f32,
        sigma: f32,
        kmax: usize,
    ) -> Vec<f32> {
        use crate::stats::norm_cdf;
        let mut u = Vec::with_capacity(kmax + 1);
        u.push(0.0);
        for &t in &self.thresholds {
            u.push(norm_cdf(((t - mu) / sigma) as f64) as f32);
        }
        while u.len() < kmax + 1 {
            u.push(1.0);
        }
        u.truncate(kmax + 1);
        u
    }
}

/// The one bin search every scalar quantizer in the codebase shares —
/// [`Quantizer::bin`] and the serving epilogue's activation-quant
/// stage (`infer::kernels::ActEp`) both delegate here, so the
/// ties-right (numpy `searchsorted(side="right")`) and totality
/// conventions can never silently diverge. `k` is the bin count
/// (`levels.len()`): ±∞ land in the outermost bins, NaN is pinned to
/// the central bin `k / 2`.
pub fn bin_total(thresholds: &[f32], k: usize, x: f32) -> usize {
    if x.is_nan() {
        return k / 2;
    }
    let mut lo = 0usize;
    let mut hi = thresholds.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if x >= thresholds[mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Trait for quantizer families: fit to data, yielding a `Quantizer`.
pub trait QuantizerFit {
    fn fit(&self, xs: &[f32], k: usize) -> Quantizer;
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q2() -> Quantizer {
        Quantizer { thresholds: vec![0.0], levels: vec![-1.0, 1.0] }
    }

    #[test]
    fn bin_search_matches_linear() {
        let q = Quantizer {
            thresholds: vec![-1.0, 0.0, 2.0],
            levels: vec![-2.0, -0.5, 1.0, 3.0],
        };
        for &(x, want) in
            &[(-5.0, 0usize), (-1.0, 1), (-0.5, 1), (0.0, 2), (1.9, 2),
              (2.0, 3), (9.0, 3)]
        {
            assert_eq!(q.bin(x), want, "x = {x}");
        }
    }

    #[test]
    fn quantize_idempotent() {
        let q = q2();
        let mut xs = vec![-3.0, -0.1, 0.1, 7.0];
        q.quantize(&mut xs);
        let once = xs.clone();
        q.quantize(&mut xs);
        assert_eq!(once, xs);
    }

    #[test]
    fn mse_zero_on_levels() {
        let q = q2();
        assert_eq!(q.mse(&[-1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn non_finite_inputs_get_valid_bins() {
        let q = Quantizer {
            thresholds: vec![-1.0, 0.0, 2.0],
            levels: vec![-2.0, -0.5, 1.0, 3.0],
        };
        assert_eq!(q.bin(f32::NEG_INFINITY), 0);
        assert_eq!(q.bin(f32::INFINITY), 3);
        // NaN pins to the central bin instead of index-walking to 0
        assert_eq!(q.bin(f32::NAN), 2);
        for x in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            assert!(q.bin(x) < q.k(), "bin({x}) out of range");
            assert!(q.quantize_one(x).is_finite());
        }
        // k = 1 (no thresholds) is total too
        let q1 = Quantizer { thresholds: vec![], levels: vec![0.5] };
        assert_eq!(q1.bin(f32::NAN), 0);
        assert_eq!(q1.bin(7.0), 0);
    }

    #[test]
    fn quantize_slice_with_nans_stays_on_levels() {
        let q = q2();
        let mut xs = vec![f32::NAN, -0.1, f32::INFINITY, f32::NEG_INFINITY];
        q.quantize(&mut xs);
        assert_eq!(xs, vec![1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn uniformized_thresholds_padded_monotone() {
        let q = Quantizer {
            thresholds: vec![-0.5, 0.5],
            levels: vec![-1.0, 0.0, 1.0],
        };
        let u = q.uniformized_thresholds(0.0, 1.0, 8);
        assert_eq!(u.len(), 9);
        assert_eq!(u[0], 0.0);
        assert_eq!(*u.last().unwrap(), 1.0);
        for w in u.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }
}
