//! k-quantile quantizers — the paper's proposed family (§3.1).
//!
//! Equiprobable bins: P(X ∈ bin_i) = 1/k. Thresholds are quantiles
//! t_i = F⁻¹(i/k) and representation levels are the bin medians
//! q_i = F⁻¹((i − ½)/k). Two fits:
//!   * Gaussian: F = Φ((x−μ)/σ) with per-tensor μ, σ — matches the
//!     in-graph Pallas `fake_quant` kernel exactly (golden-tested).
//!   * Empirical: F from the sample itself (what "updated every forward
//!     pass" would use); levels are empirical bin medians.

use super::{Quantizer, QuantizerFit};
use crate::stats::{mean_std, norm_icdf};

#[derive(Debug, Clone, Copy, Default)]
pub struct KQuantileGauss;

impl QuantizerFit for KQuantileGauss {
    fn fit(&self, xs: &[f32], k: usize) -> Quantizer {
        assert!(k >= 2);
        let s = mean_std(xs);
        let (mu, sigma) = (s.mean, s.std.max(1e-8));
        let thresholds = (1..k)
            .map(|i| (mu + sigma * norm_icdf(i as f64 / k as f64)) as f32)
            .collect();
        let levels = (0..k)
            .map(|i| {
                (mu + sigma * norm_icdf((i as f64 + 0.5) / k as f64)) as f32
            })
            .collect();
        Quantizer { thresholds, levels }
    }

    fn name(&self) -> &'static str {
        "k-quantile (gaussian)"
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct KQuantileEmpirical;

/// Linear-interpolated empirical quantile (numpy default method).
fn quantile_sorted(sorted: &[f32], q: f64) -> f32 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = (pos - lo as f64) as f32;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

fn median_of(slice: &[f32]) -> f32 {
    // slice must be sorted
    let n = slice.len();
    if n == 0 {
        return 0.0;
    }
    if n % 2 == 1 {
        slice[n / 2]
    } else {
        0.5 * (slice[n / 2 - 1] + slice[n / 2])
    }
}

impl QuantizerFit for KQuantileEmpirical {
    fn fit(&self, xs: &[f32], k: usize) -> Quantizer {
        assert!(k >= 2 && !xs.is_empty());
        let mut sorted: Vec<f32> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let thresholds: Vec<f32> = (1..k)
            .map(|i| quantile_sorted(&sorted, i as f64 / k as f64))
            .collect();
        // bin medians from the sorted sample (searchsorted side="right"
        // semantics to match Quantizer::bin and the numpy golden)
        let mut levels = Vec::with_capacity(k);
        let mut start = 0usize;
        for i in 0..k {
            let end = if i + 1 < k {
                sorted.partition_point(|&v| v < thresholds[i])
            } else {
                sorted.len()
            };
            levels.push(if end > start {
                median_of(&sorted[start..end])
            } else if i > 0 {
                // empty bin (repeated values): reuse previous level
                levels[i - 1]
            } else {
                sorted[0]
            });
            start = end;
        }
        Quantizer { thresholds, levels }
    }

    fn name(&self) -> &'static str {
        "k-quantile (empirical)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop;

    #[test]
    fn gauss_levels_symmetric_for_standard_normal_fit() {
        // construct data with mu ~ 0, sigma ~ 1
        let xs: Vec<f32> = (0..10_001)
            .map(|i| norm_icdf((i as f64 + 0.5) / 10_001.0) as f32)
            .collect();
        let q = KQuantileGauss.fit(&xs, 8);
        for i in 0..4 {
            assert!(
                (q.levels[i] + q.levels[7 - i]).abs() < 1e-3,
                "levels not symmetric: {:?}",
                q.levels
            );
        }
    }

    #[test]
    fn equiprobable_bins_property() {
        // each bin of the empirical k-quantile quantizer holds ~n/k samples
        prop(30, 101, |g| {
            let n = g.usize_in(200, 2000);
            let k = *[2usize, 4, 8, 16].get(g.usize_in(0, 3)).unwrap();
            let mu = g.f32_in(-2.0, 2.0);
            let sigma = g.f32_in(0.1, 3.0);
            let xs = g.normal_vec(n, mu, sigma);
            let q = KQuantileEmpirical.fit(&xs, k);
            let mut counts = vec![0usize; k];
            for &x in &xs {
                counts[q.bin(x)] += 1;
            }
            let expect = n as f64 / k as f64;
            for (i, &c) in counts.iter().enumerate() {
                assert!(
                    (c as f64) > 0.5 * expect && (c as f64) < 1.5 * expect,
                    "bin {i} has {c} of ~{expect} (n={n}, k={k})"
                );
            }
        });
    }

    #[test]
    fn thresholds_strictly_increasing_gauss() {
        prop(50, 102, |g| {
            let n = g.usize_in(10, 500);
            let xs = g.normal_vec(n, 0.0, 1.0);
            let k = g.usize_in(2, 32);
            let q = KQuantileGauss.fit(&xs, k);
            for w in q.thresholds.windows(2) {
                assert!(w[1] > w[0]);
            }
            assert_eq!(q.levels.len(), k);
        });
    }

    #[test]
    fn level_inside_its_bin() {
        prop(30, 103, |g| {
            let n = g.usize_in(50, 500);
            let xs = g.nasty_vec(n);
            let q = KQuantileEmpirical.fit(&xs, 8);
            for (i, &lvl) in q.levels.iter().enumerate() {
                assert_eq!(q.bin(lvl), i, "level {lvl} escaped bin {i}");
            }
        });
    }

    #[test]
    fn empirical_handles_constant_input() {
        let xs = vec![1.5f32; 100];
        let q = KQuantileEmpirical.fit(&xs, 4);
        assert!(q.levels.iter().all(|&l| l == 1.5));
    }

    #[test]
    fn quantile_interp_matches_numpy_convention() {
        let sorted = [0.0f32, 1.0, 2.0, 3.0];
        assert_eq!(quantile_sorted(&sorted, 0.5), 1.5);
        assert_eq!(quantile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(quantile_sorted(&sorted, 1.0), 3.0);
    }
}
