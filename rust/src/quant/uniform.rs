//! Uniform quantizer baseline (paper §4.3 ablation).
//!
//! Bins allocated evenly over [μ − 3σ, μ + 3σ] (the paper's ablation
//! setup), representation level = bin midpoint.

use super::{Quantizer, QuantizerFit};
use crate::stats::mean_std;

#[derive(Debug, Clone, Copy, Default)]
pub struct Uniform;

impl QuantizerFit for Uniform {
    fn fit(&self, xs: &[f32], k: usize) -> Quantizer {
        assert!(k >= 2);
        let s = mean_std(xs);
        let (mu, sigma) = (s.mean as f32, (s.std as f32).max(1e-8));
        let lo = mu - 3.0 * sigma;
        let width = 6.0 * sigma / k as f32;
        let thresholds = (1..k).map(|i| lo + width * i as f32).collect();
        let levels =
            (0..k).map(|i| lo + width * (i as f32 + 0.5)).collect();
        Quantizer { thresholds, levels }
    }

    fn name(&self) -> &'static str {
        "uniform [-3σ,3σ]"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop;

    #[test]
    fn equal_bin_widths() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 / 500.0) - 1.0).collect();
        let q = Uniform.fit(&xs, 8);
        let widths: Vec<f32> =
            q.thresholds.windows(2).map(|w| w[1] - w[0]).collect();
        for w in &widths {
            assert!((w - widths[0]).abs() < 1e-5);
        }
    }

    #[test]
    fn midpoint_levels() {
        prop(30, 201, |g| {
            let n = g.usize_in(20, 300);
            let xs = g.normal_vec(n, 0.0, 1.0);
            let k = g.usize_in(2, 16);
            let q = Uniform.fit(&xs, k);
            for i in 0..k - 2 {
                // level i is midway between thresholds i-1 and i
                if i >= 1 {
                    let mid = 0.5 * (q.thresholds[i - 1] + q.thresholds[i]);
                    assert!((q.levels[i] - mid).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn covers_centre_of_mass() {
        // quantizing N(0,1) data with a uniform quantizer keeps MSE small
        let xs: Vec<f32> = (0..4001)
            .map(|i| {
                crate::stats::norm_icdf((i as f64 + 0.5) / 4001.0) as f32
            })
            .collect();
        let q = Uniform.fit(&xs, 16);
        // bin width 6/16 sigma -> MSE ~ width^2/12 ~ 0.012
        assert!(q.mse(&xs) < 0.02);
    }
}
