//! Power-law companding quantizer family (PowerQuant-style automorphism;
//! LCQ's fixed-form cousin): weights are quantized on a uniform grid in
//! the companded domain `y = sign(x)·|x|^alpha`, and the thresholds and
//! levels are mapped back through the inverse before they leave the fit.
//!
//! Because the map is strictly monotone, binning in x against the mapped
//! thresholds is equivalent to binning the companded value in y — and the
//! codebook LUT stores the *decoded* levels, so serving absorbs the
//! inverse map for free: v2/v3 execute a power-companded layer exactly
//! like any other codebook, bit-identically (DESIGN §16).

use super::{Quantizer, QuantizerFit, Uniform};
use crate::stats::norm_icdf;

/// Alpha grid searched by `fit_best`. Contains 1.0 (the identity map),
/// so power-compand never loses to the plain uniform grid in
/// reconstruction MSE; values < 1 densify bins near zero (where weight
/// mass concentrates), 1.5 spreads them toward the tails.
pub const ALPHA_GRID: [f32; 7] = [0.25, 0.4, 0.5, 2.0 / 3.0, 0.8, 1.0, 1.5];

/// `sign(x)·|x|^alpha` — strictly increasing for alpha > 0, odd, fixes 0.
pub fn compand(alpha: f32, x: f32) -> f32 {
    if x == 0.0 {
        0.0
    } else {
        x.signum() * x.abs().powf(alpha)
    }
}

/// Inverse of `compand(alpha, ·)` (same family with exponent 1/alpha).
pub fn decompand(alpha: f32, y: f32) -> f32 {
    compand(1.0 / alpha, y)
}

#[derive(Debug, Clone, Copy)]
pub struct PowerCompand {
    pub alpha: f32,
}

impl Default for PowerCompand {
    fn default() -> Self {
        PowerCompand { alpha: 0.5 }
    }
}

impl PowerCompand {
    /// Grid-search alpha minimizing reconstruction MSE. Strict `<` with
    /// first-wins ties keeps the result deterministic, and since the
    /// grid contains 1.0 the winner is never worse than `Uniform`.
    pub fn fit_best(xs: &[f32], k: usize) -> (f32, Quantizer) {
        let mut best: Option<(f32, Quantizer, f64)> = None;
        for &alpha in ALPHA_GRID.iter() {
            let q = PowerCompand { alpha }.fit(xs, k);
            let mse = q.mse(xs);
            if best.as_ref().map_or(true, |(_, _, m)| mse < *m) {
                best = Some((alpha, q, mse));
            }
        }
        let (alpha, q, _) = best.unwrap();
        (alpha, q)
    }

    /// Data-free fit on the standard normal: alpha-grid search over a
    /// centre-of-mass sample grid (`norm_icdf((i+0.5)/n)`, the same grid
    /// the Uniform coverage test uses). Scale by σ and shift by μ at the
    /// use site, like `KMeans::fit_gaussian`.
    pub fn fit_best_gaussian(k: usize) -> (f32, Quantizer) {
        let n = 4001usize;
        let xs: Vec<f32> = (0..n)
            .map(|i| norm_icdf((i as f64 + 0.5) / n as f64) as f32)
            .collect();
        Self::fit_best(&xs, k)
    }
}

impl QuantizerFit for PowerCompand {
    fn fit(&self, xs: &[f32], k: usize) -> Quantizer {
        assert!(k >= 2 && !xs.is_empty());
        assert!(
            self.alpha.is_finite() && self.alpha > 0.0,
            "compand alpha must be positive, got {}",
            self.alpha
        );
        // alpha == 1 must *reduce exactly* to the uniform grid; the
        // powf(1.0) float round-trip is not guaranteed bit-identical,
        // so delegate instead of companding through the identity.
        if self.alpha == 1.0 {
            return Uniform.fit(xs, k);
        }
        let ys: Vec<f32> = xs.iter().map(|&x| compand(self.alpha, x)).collect();
        let q = Uniform.fit(&ys, k);
        Quantizer {
            thresholds: q
                .thresholds
                .iter()
                .map(|&t| decompand(self.alpha, t))
                .collect(),
            levels: q
                .levels
                .iter()
                .map(|&l| decompand(self.alpha, l))
                .collect(),
        }
    }

    fn name(&self) -> &'static str {
        "power-compand"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn alpha_one_is_exactly_the_uniform_grid() {
        let xs = gaussian(2000, 11);
        for k in [2usize, 4, 16] {
            let p = PowerCompand { alpha: 1.0 }.fit(&xs, k);
            let u = Uniform.fit(&xs, k);
            assert_eq!(p.thresholds, u.thresholds, "k={k}");
            assert_eq!(p.levels, u.levels, "k={k}");
        }
    }

    #[test]
    fn compand_is_odd_and_strictly_monotone() {
        for &alpha in ALPHA_GRID.iter() {
            let pts: Vec<f32> =
                (-20..=20).map(|i| i as f32 * 0.17).collect();
            for w in pts.windows(2) {
                assert!(
                    compand(alpha, w[0]) < compand(alpha, w[1]),
                    "alpha {alpha}: not increasing at {w:?}"
                );
            }
            for &x in pts.iter() {
                assert_eq!(compand(alpha, -x), -compand(alpha, x));
                let rt = decompand(alpha, compand(alpha, x));
                assert!((rt - x).abs() <= 1e-4 * x.abs().max(1.0));
            }
        }
    }

    #[test]
    fn thresholds_sorted_levels_interleave_for_all_alphas() {
        let xs = gaussian(3000, 5);
        for &alpha in ALPHA_GRID.iter() {
            let q = PowerCompand { alpha }.fit(&xs, 16);
            assert_eq!(q.k(), 16);
            for w in q.thresholds.windows(2) {
                assert!(w[0] < w[1], "alpha {alpha}: {:?}", q.thresholds);
            }
            for i in 0..q.thresholds.len() {
                assert!(
                    q.levels[i] < q.thresholds[i]
                        && q.thresholds[i] < q.levels[i + 1],
                    "alpha {alpha}: level/threshold interleaving broken"
                );
            }
        }
    }

    #[test]
    fn levels_quantize_to_themselves() {
        let xs = gaussian(2000, 3);
        for &alpha in ALPHA_GRID.iter() {
            let q = PowerCompand { alpha }.fit(&xs, 8);
            for (i, &l) in q.levels.iter().enumerate() {
                assert_eq!(q.bin(l), i, "alpha {alpha} level {i}");
            }
        }
    }

    /// Heavy-tailed data (product of two normals, excess kurtosis like
    /// a trained weight tensor with outliers): companding wins, and by
    /// a wide margin (mirror-verified pw/un ratios 0.42–0.74).
    #[test]
    fn best_alpha_on_heavy_tails_compresses_and_beats_uniform() {
        let mut r = Rng::new(9);
        let xs: Vec<f32> =
            (0..4000).map(|_| r.normal() * r.normal() * 0.2).collect();
        for k in [4usize, 8, 16] {
            let (alpha, q) = PowerCompand::fit_best(&xs, k);
            let un = Uniform.fit(&xs, k).mse(&xs);
            let pw = q.mse(&xs);
            assert!(pw < un, "k={k}: power {pw} not below uniform {un}");
            assert!(
                alpha < 1.0,
                "k={k}: heavy tails should prefer compression, got {alpha}"
            );
        }
    }

    /// On a PURE Gaussian the identity map wins: alpha < 1 piles
    /// resolution into a neighbourhood of zero that a Gaussian doesn't
    /// overweight enough to pay for the coarsened shoulders. fit_best
    /// must therefore return alpha = 1.0 (never worse than Uniform by
    /// construction) — a regression test for the grid containing 1.0.
    #[test]
    fn best_alpha_on_pure_gaussian_is_identity() {
        let xs = gaussian(4000, 9);
        for k in [4usize, 8, 16] {
            let (alpha, q) = PowerCompand::fit_best(&xs, k);
            assert_eq!(alpha, 1.0, "k={k}");
            let un = Uniform.fit(&xs, k);
            assert_eq!(q.thresholds, un.thresholds, "k={k}");
            assert_eq!(q.levels, un.levels, "k={k}");
        }
    }

    #[test]
    fn gaussian_table_is_symmetric_and_ordered() {
        let (_, q) = PowerCompand::fit_best_gaussian(8);
        for i in 0..4 {
            assert!(
                (q.levels[i] + q.levels[7 - i]).abs() < 2e-2,
                "{:?}",
                q.levels
            );
        }
        for w in q.levels.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
