//! Host-quantizer microbenchmarks (the coordinator's freeze hot path).
//!
//! The gradual schedule quantizes one block per phase; for big layers the
//! fit+quantize must stay negligible next to a train step (~100 ms).

use uniq::quant::{
    KMeans, KQuantileEmpirical, KQuantileGauss, QuantizerFit, Uniform,
};
use uniq::stats::{norm_cdf, norm_icdf, shapiro_wilk};
use uniq::util::bench::Bench;
use uniq::util::rng::Rng;

fn main() {
    let mut b = Bench::new("quantizers");
    let mut rng = Rng::new(7);
    for n in [10_000usize, 1_000_000] {
        let data: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
        let label = if n >= 1_000_000 { "1M" } else { "10k" };

        b.run_throughput(&format!("fit/kquantile_gauss/{label}"), n, || {
            KQuantileGauss.fit(&data, 16)
        });
        b.run_throughput(&format!("fit/kquantile_empirical/{label}"), n, || {
            KQuantileEmpirical.fit(&data, 16)
        });
        b.run_throughput(&format!("fit/kmeans/{label}"), n, || {
            KMeans::default().fit(&data, 16)
        });
        b.run_throughput(&format!("fit/uniform/{label}"), n, || {
            Uniform.fit(&data, 16)
        });

        let q = KQuantileGauss.fit(&data, 16);
        let mut buf = data.clone();
        b.run_throughput(&format!("quantize/k16/{label}"), n, || {
            buf.copy_from_slice(&data);
            q.quantize(&mut buf);
        });
        let q256 = KQuantileGauss.fit(&data, 256);
        b.run_throughput(&format!("quantize/k256/{label}"), n, || {
            buf.copy_from_slice(&data);
            q256.quantize(&mut buf);
        });
    }

    // special functions used per-element by the host paths
    let zs: Vec<f64> = (0..4096).map(|i| -4.0 + i as f64 / 512.0).collect();
    b.run_throughput("norm_cdf/4k", zs.len(), || {
        zs.iter().map(|&z| norm_cdf(z)).sum::<f64>()
    });
    let us: Vec<f64> = (1..4096).map(|i| i as f64 / 4096.0).collect();
    b.run_throughput("norm_icdf/4k", us.len(), || {
        us.iter().map(|&u| norm_icdf(u)).sum::<f64>()
    });

    // Fig C.1 path: Shapiro-Wilk on a 2000-sample layer
    let sample: Vec<f32> = (0..2000).map(|_| rng.normal()).collect();
    b.run("shapiro_wilk/2000", || shapiro_wilk(&sample));

    b.finish();
}
