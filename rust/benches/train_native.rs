//! Native train-step benchmarks → `BENCH_train.json`.
//!
//! Runs everywhere (synthetic manifest, no artifacts, no PJRT): one SGD
//! step of the mlp family per schedule mode (full precision / UNIQ noise
//! injection / frozen), across worker-thread counts, plus the eval step
//! and the host freeze. The JSON report records median/p10/p90 per cell
//! and the measured thread-scaling ratio of the noise-mode step.

use uniq::coordinator::FreezeQuant;
use uniq::data::synth::{SynthConfig, SynthDataset};
use uniq::data::Batcher;
use uniq::infer::synthetic;
use uniq::runtime::state::StepConfig;
use uniq::runtime::Backend;
use uniq::train::NativeBackend;
use uniq::util::bench::Bench;
use uniq::util::json::{num, obj, s, Json};

fn main() {
    let mut b = Bench::quick("train_native");
    b.min_time = std::time::Duration::from_millis(400);

    let (m, state) = synthetic::mlp(256, 10, 7);
    let data = SynthDataset::generate(SynthConfig {
        n: 64,
        ..Default::default()
    });
    let batch = Batcher::eval_batches(&data, m.batch).remove(0);
    let n_layers = m.n_qlayers();
    let max_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 8);

    let cfg_for = |mode: f32| StepConfig {
        lr: 1e-3,
        k_w: 16.0,
        k_a: 256.0,
        aq: 0.0,
        seed: 1,
        mode_vec: vec![mode; n_layers],
        qthresh: None,
    };

    let mut jcells = Vec::new();
    let mut noise_medians = Vec::new();
    // single-core hosts would otherwise bench threads=1 twice
    let thread_counts: Vec<usize> =
        if max_threads > 1 { vec![1, max_threads] } else { vec![1] };
    for threads in thread_counts {
        let backend = NativeBackend::new(&m).unwrap().with_threads(threads);
        for (label, mode) in
            [("fp", 0.0f32), ("noise", 1.0), ("frozen", 2.0)]
        {
            let cfg = cfg_for(mode);
            let mut st = state.clone();
            let stats = b.run(
                &format!("mlp/train/{label}/t{threads}"),
                || {
                    backend
                        .train_step(&m, &mut st, &batch.x, &batch.y, &cfg)
                        .expect("train step")
                },
            );
            if label == "noise" {
                noise_medians.push((threads, stats.median_ns));
            }
            jcells.push(obj(vec![
                ("mode", s(label)),
                ("threads", num(threads as f64)),
                ("stats", stats.to_json()),
            ]));
        }
        let st = state.clone();
        b.run(&format!("mlp/eval/t{threads}"), || {
            backend
                .eval_step(&m, &st, &batch.x, &batch.y, 256.0, 1.0)
                .expect("eval step")
        });
        if threads == 1 {
            // host freeze of the biggest layer (backend-independent path)
            let w = state.params[0].clone();
            b.run("mlp/freeze_biggest_layer", || {
                let q = FreezeQuant::KQuantileGauss.fit(&w, 16);
                let mut wq = w.clone();
                q.quantize(&mut wq);
                wq
            });
        }
    }

    let speedup = match (noise_medians.first(), noise_medians.last()) {
        (Some((1, t1)), Some((tn, tns))) if *tn > 1 => {
            Some((*tn, t1 / tns))
        }
        _ => None,
    };
    if let Some((tn, sp)) = speedup {
        println!("noise-step thread scaling: {sp:.2}x at {tn} threads");
    }

    let report = obj(vec![
        ("bench", s("train_native")),
        ("model", s("mlp")),
        ("batch", num(batch.n as f64)),
        ("bits_w", num(4.0)),
        ("cells", Json::Arr(jcells)),
        (
            "noise_step_thread_speedup",
            speedup.map(|(_, sp)| num(sp)).unwrap_or(Json::Null),
        ),
        ("all_runs", b.report_json()),
        (
            "note",
            s("median_ns per native train/eval step; modes are the \
               schedule's LayerMode codes"),
        ),
    ]);
    std::fs::write("BENCH_train.json", report.to_string())
        .expect("writing BENCH_train.json");
    println!("[written] BENCH_train.json");
    b.finish();
}
