//! Native inference benchmarks: v2 LUT engine (tiled + fused + arena)
//! vs the PR-1 v1 engine vs dequantized-f32 vs the PJRT eval step, at
//! serving batch sizes 1 / 8 / 32 / 64, plus a kernel-level LUT-GEMM
//! micro-benchmark, a serve-tier v1-vs-v2 A/B at equal worker count and
//! a router-tier 1-vs-3-replica A/B at equal TOTAL worker count.
//! Emits `BENCH_inference.json` (machine-readable, `util::bench` stats).
//!
//! Runs everywhere: models are synthetic UNIQ-frozen replicas of the AOT
//! variants; the PJRT column appears only when artifacts and a real xla
//! backend are present (recorded as null otherwise, with the reason).
//!
//! CI uploads the JSON as an artifact and gates on
//! `python/tools/bench_compare.py` against the committed baseline
//! (`rust/benches/baseline/BENCH_inference.json`): fail below the hard
//! throughput threshold, warn below the soft one.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use uniq::coordinator::FreezeQuant;
use uniq::data::synth::{SynthConfig, SynthDataset};
use uniq::data::Batcher;
use uniq::infer::net::{
    submit_blocking, RemoteOpts, RemoteReplica, Worker,
};
use uniq::infer::{
    kernels, synthetic, ActQuantTable, AqMode, ExecBuffers, FrozenModel,
    KernelMode, PackedBits, Router, RouterConfig, RoutingPolicy,
    ServeConfig, ServeModel, Server,
};
use uniq::quant::{KQuantileGauss, QuantizerFit};
use uniq::util::bench::Bench;
use uniq::util::json::{num, obj, s, Json};
use uniq::util::rng::Rng;

// 32 is the AOT variants' native batch — the only size the fixed-batch
// PJRT executables can be compared at.
const BATCHES: [usize; 4] = [1, 8, 32, 64];

fn threads_avail() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(1, 8)
}

/// Kernel-level v1-vs-v2-vs-v3 micro-benchmark on a conv-shaped GEMM
/// (batch-8 mobilenet pointwise layer scale). Returns the JSON block
/// plus the v3-vs-v2 speedup for the top-level ratio table.
fn kernel_micro(b: &mut Bench, threads: usize) -> (Json, f64) {
    let (rows, cin, cout) = (2048usize, 144usize, 32usize);
    let mut rng = Rng::new(97);
    let x: Vec<f32> = (0..rows * cin).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..cin * cout).map(|_| rng.normal()).collect();
    let q = KQuantileGauss.fit(&w, 16);
    let idx: Vec<u8> = w.iter().map(|&v| q.bin(v) as u8).collect();
    let idx_t = kernels::transpose_idx(&idx, cin, cout);
    let mut out = vec![0.0f32; rows * cout];
    let name = format!("lut_gemm/{rows}x{cin}x{cout}");

    let v1 = b.run(&format!("{name}/v1"), || {
        kernels::lut_matmul(&x, &idx_t, &q.levels, rows, cin, cout, &mut out);
    });
    let mut pool = kernels::GemmScratchPool::new();
    let v2 = b.run(&format!("{name}/v2_t1"), || {
        kernels::lut_matmul_tiled(
            &x,
            &idx_t,
            &q.levels,
            rows,
            cin,
            cout,
            &mut out,
            kernels::Epilogue::default(),
            1,
            &mut pool,
        );
    });
    let v2_mt = b.run(&format!("{name}/v2_t{threads}"), || {
        kernels::lut_matmul_tiled(
            &x,
            &idx_t,
            &q.levels,
            rows,
            cin,
            cout,
            &mut out,
            kernels::Epilogue::default(),
            threads,
            &mut pool,
        );
    });
    // v3 LUT²: the same GEMM consuming a 4-bit activation-index
    // stream against the packed weight indices through the product
    // table — the integer-only hot path
    let t = ActQuantTable::from_stats(AqMode::Quantile, 4, 0.0, 1.0);
    let aep = t.ep();
    let qa: Vec<u8> = x.iter().map(|&v| aep.bin(v) as u8).collect();
    let (table, stride) = t.product_table(&q.levels);
    let widx = PackedBits::pack(&idx_t, 4);
    let v3 = b.run(&format!("{name}/v3"), || {
        kernels::lut2_matmul(
            &qa,
            &widx,
            &table,
            stride,
            rows,
            cin,
            cout,
            &mut out,
            kernels::Epilogue::default(),
            1,
            &mut pool,
        );
    });
    let v3_ratio = v2.median_ns / v3.median_ns;
    (
        obj(vec![
            ("shape", s(&format!("{rows}x{cin}x{cout}"))),
            ("threads_mt", num(threads as f64)),
            ("v1", v1.to_json()),
            ("v2_t1", v2.to_json()),
            ("v2_mt", v2_mt.to_json()),
            ("v3", v3.to_json()),
            ("v2_vs_v1_speedup", num(v1.median_ns / v2.median_ns)),
            ("v2_mt_vs_v1_speedup", num(v1.median_ns / v2_mt.median_ns)),
            ("v3_vs_v2_speedup", num(v3_ratio)),
        ]),
        v3_ratio,
    )
}

/// v3-vs-v2 A/B on the acceptance configuration (mobilenet_mini,
/// quantile-4 aq): the same calibrated model through both engines with
/// per-engine persistent arenas, batch 1 and 64. Asserts bit-identity
/// before timing — a perf number for a wrong kernel is worse than no
/// number. Returns the JSON block plus named speedup ratios for the
/// top-level `ratios` table (gated as absolute factors by
/// bench_compare).
fn v3_ab(
    b: &mut Bench,
    calib: &[f32],
    img_len: usize,
) -> (Json, Vec<(String, f64)>) {
    let (m, state) = synthetic::model("mobilenet_mini", 16, 10, 7).unwrap();
    let frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    let mut sm = ServeModel::new(frozen).unwrap();
    sm.calibrate_aq(AqMode::Quantile, 4, calib, 32).unwrap();
    let mut ratios = Vec::new();
    let mut jbatches = Vec::new();
    for batch in [1usize, 64] {
        let x = &calib[..batch * img_len];
        let mut bufs2 = ExecBuffers::new();
        let mut bufs3 = ExecBuffers::new();
        {
            let a = sm
                .graph
                .forward_into(
                    &sm.model, &sm.weights, x, batch, KernelMode::Lut,
                    &mut bufs2,
                )
                .unwrap()
                .to_vec();
            let bb = sm
                .graph
                .forward_into(
                    &sm.model, &sm.weights, x, batch, KernelMode::LutV3,
                    &mut bufs3,
                )
                .unwrap()
                .to_vec();
            assert_eq!(a, bb, "v3 != v2 at batch {batch}; not timing a lie");
        }
        let v2 = b.run_throughput(
            &format!("mobilenet_mini/lut_v2_aq/b{batch}"),
            batch,
            || {
                sm.graph
                    .forward_into(
                        &sm.model, &sm.weights, x, batch, KernelMode::Lut,
                        &mut bufs2,
                    )
                    .unwrap();
            },
        );
        let v3 = b.run_throughput(
            &format!("mobilenet_mini/lut_v3/b{batch}"),
            batch,
            || {
                sm.graph
                    .forward_into(
                        &sm.model, &sm.weights, x, batch,
                        KernelMode::LutV3, &mut bufs3,
                    )
                    .unwrap();
            },
        );
        let ratio = v2.median_ns / v3.median_ns;
        println!(
            "v3[b{batch}]: v2-aq {:.0} ns, v3 {:.0} ns ({ratio:.2}x)",
            v2.median_ns, v3.median_ns
        );
        ratios.push((format!("v3_vs_v2_batch{batch}"), ratio));
        jbatches.push(obj(vec![
            ("batch", num(batch as f64)),
            ("lut_v2_aq", v2.to_json()),
            ("lut_v3", v3.to_json()),
            ("v3_vs_v2_speedup", num(ratio)),
        ]));
    }
    let j = obj(vec![
        ("model", s("mobilenet_mini")),
        ("aq", s("quantile4")),
        ("v3_table_bytes", num(sm.weights.v3_table_bytes() as f64)),
        ("batches", Json::Arr(jbatches)),
        (
            "note",
            s("same calibrated model, per-engine persistent arenas; \
               speedups are v2-aq median / v3 median at equal batch"),
        ),
    ]);
    (j, ratios)
}

/// Serve-tier A/B: identical traffic through the v1 and v2 engines at
/// equal worker count; records throughput for both.
fn serve_ab(sm: &Arc<ServeModel>, img_len: usize, n_requests: usize) -> Json {
    let workers = threads_avail().min(4);
    let mut results = Vec::new();
    for (label, mode) in
        [("v1", KernelMode::LutV1), ("v2", KernelMode::Lut)]
    {
        let srv = Server::start(
            Arc::clone(sm),
            ServeConfig {
                workers,
                max_batch: 64,
                max_wait: Duration::from_millis(2),
                mode,
                kernel_threads: 1,
                shed_after: None,
            },
        );
        let mut rng = Rng::new(5);
        let pending: Vec<_> = (0..n_requests)
            .map(|_| {
                let img: Vec<f32> =
                    (0..img_len).map(|_| rng.normal()).collect();
                srv.submit(img).unwrap()
            })
            .collect();
        for rx in pending {
            rx.recv().expect("serve reply");
        }
        let stats = srv.shutdown();
        println!(
            "serve[{label}] x{workers} workers: {:.0} img/s (p50 {:.2} ms)",
            stats.throughput_rps, stats.p50_ms
        );
        results.push((label, stats));
    }
    let v1_rps = results[0].1.throughput_rps;
    let v2_rps = results[1].1.throughput_rps;
    obj(vec![
        ("workers", num(workers as f64)),
        ("requests", num(n_requests as f64)),
        ("v1", results[0].1.to_json()),
        ("v2", results[1].1.to_json()),
        (
            "v2_vs_v1_throughput",
            num(if v1_rps > 0.0 { v2_rps / v1_rps } else { 0.0 }),
        ),
    ])
}

/// Router-tier A/B: identical batch-1 traffic through one replica with
/// the whole worker budget vs a 3-replica fleet splitting the same
/// budget — equal total worker count, so the recorded delta is the
/// replicated front door (per-replica collectors/queues), not extra
/// cores.
fn router_fleet_ab(
    sm: &Arc<ServeModel>,
    img_len: usize,
    n_requests: usize,
) -> Json {
    // worker budget divisible by the fleet size so the split is exact
    let total_workers = if threads_avail() >= 6 { 6 } else { 3 };
    let mut results = Vec::new();
    for replicas in [1usize, 3] {
        let router = Router::start(
            Arc::clone(sm),
            RouterConfig {
                replicas,
                policy: RoutingPolicy::PowerOfTwo,
                queue_cap: 8192,
                health_every: Duration::from_millis(5),
                max_retries: 4,
                seed: 23,
                request_timeout: None,
                breaker_threshold: 3,
                breaker_cooldown: Duration::from_millis(250),
                serve: ServeConfig {
                    workers: (total_workers / replicas).max(1),
                    max_batch: 1, // batch-1 traffic: front-door bound
                    max_wait: Duration::ZERO,
                    mode: KernelMode::Lut,
                    kernel_threads: 1,
                    shed_after: None,
                },
            },
        );
        let mut rng = Rng::new(7);
        let images: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..img_len).map(|_| rng.normal()).collect())
            .collect();
        let pending: Vec<_> = (0..n_requests)
            .map(|i| router.submit(&images[i % images.len()]).unwrap())
            .collect();
        for p in pending {
            p.recv().expect("fleet reply");
        }
        let fleet = router.shutdown();
        println!(
            "router[x{replicas}] {total_workers} workers total: {:.0} \
             img/s (p50 {:.2} ms)",
            fleet.fleet.throughput_rps, fleet.fleet.p50_ms
        );
        results.push(fleet);
    }
    let one_rps = results[0].fleet.throughput_rps;
    let three_rps = results[1].fleet.throughput_rps;
    obj(vec![
        ("total_workers", num(total_workers as f64)),
        ("requests", num(n_requests as f64)),
        ("policy", s("power-of-two")),
        ("traffic", s("batch-1")),
        ("replicas1", results[0].fleet.to_json()),
        ("replicas3", results[1].fleet.to_json()),
        (
            "fleet_3x_vs_1x_throughput",
            num(if one_rps > 0.0 { three_rps / one_rps } else { 0.0 }),
        ),
    ])
}

/// Loopback wire-transport overhead: identical batch-1 round trips
/// through an in-process `Server` vs a `RemoteReplica` speaking the
/// `infer::net` frame protocol to an in-process worker over 127.0.0.1.
/// The recorded ratio prices the frame codec + TCP + reader/pump
/// threads — the per-request cost of taking a replica slot across a
/// process boundary. A third leg re-runs the remote round trips with
/// an aggressive 5 ms heartbeat armed, pricing the liveness layer
/// (pings sharing the writer lock, pongs sharing the reader) against
/// the plain connection; the returned factor is
/// plain median / heartbeat median (1.0 = heartbeats are free).
fn remote_loopback(
    b: &mut Bench,
    sm: &Arc<ServeModel>,
    img_len: usize,
) -> (Json, f64) {
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::ZERO,
        mode: KernelMode::Lut,
        kernel_threads: 1,
        shed_after: None,
    };
    let mut rng = Rng::new(41);
    let imgs: Vec<Vec<f32>> = (0..32)
        .map(|_| (0..img_len).map(|_| rng.normal()).collect())
        .collect();

    let srv = Server::start(Arc::clone(sm), cfg.clone());
    let mut i = 0usize;
    let inproc =
        b.run_throughput("mobilenet_mini/inproc_b1", 1, || {
            let rx = srv.submit(imgs[i % imgs.len()].clone()).unwrap();
            rx.recv().unwrap();
            i += 1;
        });
    srv.shutdown();

    let worker =
        Worker::bind(Arc::clone(sm), cfg, "127.0.0.1:0").unwrap();
    let addr = worker.addr().to_string();
    let handle = worker.spawn();
    // plain leg: heartbeats explicitly OFF so the key keeps measuring
    // the bare wire cost it always has
    let replica = RemoteReplica::connect(
        &addr,
        None,
        RemoteOpts { heartbeat_every: None, ..RemoteOpts::default() },
        Arc::new(std::sync::atomic::AtomicUsize::new(0)),
    )
    .unwrap();
    let mut j = 0usize;
    let remote =
        b.run_throughput("mobilenet_mini/remote_b1", 1, || {
            let rx = submit_blocking(
                &replica,
                imgs[j % imgs.len()].clone(),
                Duration::from_secs(5),
            )
            .unwrap();
            rx.recv().unwrap();
            j += 1;
        });
    let _ = replica.drain_then_stop();

    // heartbeat leg: 5 ms pings interleave with the bench traffic on
    // the same writer lock and reader thread
    let hb_replica = RemoteReplica::connect(
        &addr,
        None,
        RemoteOpts {
            heartbeat_every: Some(Duration::from_millis(5)),
            ..RemoteOpts::default()
        },
        Arc::new(std::sync::atomic::AtomicUsize::new(0)),
    )
    .unwrap();
    let mut k = 0usize;
    let hb = b.run_throughput("mobilenet_mini/remote_b1_hb", 1, || {
        let rx = submit_blocking(
            &hb_replica,
            imgs[k % imgs.len()].clone(),
            Duration::from_secs(5),
        )
        .unwrap();
        rx.recv().unwrap();
        k += 1;
    });
    let _ = hb_replica.drain_then_stop();
    handle.shutdown();

    let hb_vs_plain = remote.median_ns / hb.median_ns;
    println!(
        "remote loopback b1: inproc {:.0} ns, remote {:.0} ns \
         ({:.2}x round-trip cost), heartbeat-armed {:.0} ns \
         ({:.2}x vs plain)",
        inproc.median_ns,
        remote.median_ns,
        remote.median_ns / inproc.median_ns,
        hb.median_ns,
        1.0 / hb_vs_plain
    );
    let report = obj(vec![
        ("traffic", s("batch-1 round trip, single worker, loopback")),
        ("inproc", inproc.to_json()),
        ("remote", remote.to_json()),
        ("remote_hb", hb.to_json()),
        (
            "remote_vs_inproc_batch1",
            num(remote.median_ns / inproc.median_ns),
        ),
    ]);
    (report, hb_vs_plain)
}

/// Accuracy-vs-BOPS frontier data: forward throughput + analytic BOPS
/// per activation-quant config on mobilenet_mini — (none, uniform-4,
/// quantile-4), the acceptance set. BOPS are the REAL served per-layer
/// `b_w × b_a` (`Graph::served_complexity`): a layer prices at the
/// width of the tensor it reads — f32 image input and pooled
/// classifier input stay 32-bit, everything fed by a quantized output
/// prices at the table width. Before this the recorded numbers were
/// implicitly weight-only.
fn aq_configs(b: &mut Bench, calib: &[f32], img_len: usize) -> Json {
    let (m, state) = synthetic::model("mobilenet_mini", 16, 10, 7).unwrap();
    let frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    let batch = 32usize;
    let mut jconfigs = Vec::new();
    for (label, mode) in [
        ("none", None),
        ("uniform4", Some(AqMode::Uniform)),
        ("quantile4", Some(AqMode::Quantile)),
    ] {
        let mut sm = ServeModel::new(frozen.clone()).unwrap();
        if let Some(mode) = mode {
            sm.calibrate_aq(mode, 4, calib, batch).unwrap();
        }
        let c = sm.graph.served_complexity(&sm.model);
        let x = &calib[..batch * img_len];
        let mut bufs = ExecBuffers::new();
        let run = b.run_throughput(
            &format!("mobilenet_mini/aq_{label}/b{batch}"),
            batch,
            || {
                sm.graph
                    .forward_into(
                        &sm.model,
                        &sm.weights,
                        x,
                        batch,
                        KernelMode::Lut,
                        &mut bufs,
                    )
                    .unwrap();
            },
        );
        println!(
            "aq[{label}] w{}a{}: {:.4} GBOPs/img at {:.0} ns/batch{batch}",
            sm.model.bits_w,
            sm.model.bits_a(),
            c.gbops(),
            run.median_ns
        );
        jconfigs.push(obj(vec![
            ("mode", s(label)),
            ("bits_w", num(sm.model.bits_w as f64)),
            ("bits_a", num(sm.model.bits_a() as f64)),
            ("gbops_per_img", num(c.gbops())),
            ("run", run.to_json()),
        ]));
    }
    obj(vec![
        ("model", s("mobilenet_mini")),
        ("batch", num(batch as f64)),
        ("configs", Json::Arr(jconfigs)),
        (
            "note",
            s("gbops_per_img is the analytic served complexity at the \
               config's real b_w x b_a; run.median_ns is the v2 forward \
               at the stated batch"),
        ),
    ])
}

fn main() {
    let mut b = Bench::quick("inference");
    b.min_time = std::time::Duration::from_millis(400);
    let threads = threads_avail();
    let data = SynthDataset::generate(SynthConfig {
        n: 64,
        ..Default::default()
    });
    let probe = Batcher::eval_batches(&data, 64).remove(0);

    let mut jmodels = Vec::new();
    let mut serve_json = Json::Null;
    let mut fleet_json = Json::Null;
    let mut remote_json = Json::Null;
    let mut remote_hb_ratio = 1.0f64;
    for (name, width) in [("mobilenet_mini", 16usize), ("mlp", 16)] {
        let (m, state) = synthetic::model(name, width, 10, 7).unwrap();
        let frozen =
            FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
                .unwrap();
        let sm = Arc::new(ServeModel::new(frozen).unwrap());
        let mut jbatches = Vec::new();
        for batch in BATCHES {
            let x = &probe.x[..batch * data.image_len()];
            // v2 engine through a persistent arena (the serving form)
            let mut bufs = ExecBuffers::new();
            let lut = b.run_throughput(
                &format!("{name}/lut_v2/b{batch}"),
                batch,
                || {
                    sm.graph
                        .forward_into(
                            &sm.model,
                            &sm.weights,
                            x,
                            batch,
                            KernelMode::Lut,
                            &mut bufs,
                        )
                        .unwrap();
                },
            );
            // v2 engine, row-sharded GEMMs
            let mut bufs_mt = ExecBuffers::with_threads(threads);
            let lut_mt = b.run_throughput(
                &format!("{name}/lut_v2_t{threads}/b{batch}"),
                batch,
                || {
                    sm.graph
                        .forward_into(
                            &sm.model,
                            &sm.weights,
                            x,
                            batch,
                            KernelMode::Lut,
                            &mut bufs_mt,
                        )
                        .unwrap();
                },
            );
            // the PR-1 engine (recorded baseline)
            let lut_v1 = b.run_throughput(
                &format!("{name}/lut_v1/b{batch}"),
                batch,
                || {
                    sm.graph
                        .forward(
                            &sm.model,
                            &sm.weights,
                            x,
                            batch,
                            KernelMode::LutV1,
                        )
                        .unwrap()
                },
            );
            let deq = b.run_throughput(
                &format!("{name}/dequant_f32/b{batch}"),
                batch,
                || {
                    sm.graph
                        .forward(
                            &sm.model,
                            &sm.weights,
                            x,
                            batch,
                            KernelMode::DequantF32,
                        )
                        .unwrap()
                },
            );
            let pjrt = uniq::runtime::bench_eval_step(
                &mut b,
                &Path::new("artifacts").join(name),
                batch,
                x,
            );
            jbatches.push(obj(vec![
                ("batch", num(batch as f64)),
                ("lut", lut.to_json()),
                ("lut_mt", lut_mt.to_json()),
                ("lut_v1", lut_v1.to_json()),
                ("dequant_f32", deq.to_json()),
                ("pjrt", pjrt.map(|p| p.to_json()).unwrap_or(Json::Null)),
                ("lut_vs_f32_speedup", num(deq.median_ns / lut.median_ns)),
                (
                    "v2_vs_v1_speedup",
                    num(lut_v1.median_ns / lut.median_ns),
                ),
                (
                    "v2_mt_vs_v1_speedup",
                    num(lut_v1.median_ns / lut_mt.median_ns),
                ),
            ]));
        }
        if name == "mobilenet_mini" {
            serve_json = serve_ab(&sm, data.image_len(), 512);
            fleet_json = router_fleet_ab(&sm, data.image_len(), 512);
            let (rj, hb_ratio) =
                remote_loopback(&mut b, &sm, data.image_len());
            remote_json = rj;
            remote_hb_ratio = hb_ratio;
        }
        jmodels.push(obj(vec![
            ("model", s(name)),
            ("bits_w", num(4.0)),
            ("batches", Json::Arr(jbatches)),
        ]));
    }

    let (jkernel, kernel_ratio) = kernel_micro(&mut b, threads);
    let jaq = aq_configs(&mut b, &probe.x, data.image_len());
    let (jv3, v3_ratios) = v3_ab(&mut b, &probe.x, data.image_len());

    // absolute speedup factors, gated by bench_compare as
    // rel = now/base (NOT re-normalized throughput)
    let mut ratio_pairs: Vec<(&str, Json)> = v3_ratios
        .iter()
        .map(|(k, v)| (k.as_str(), num(*v)))
        .collect();
    ratio_pairs.push(("v3_vs_v2_kernel", num(kernel_ratio)));
    // liveness-layer cost gate: plain remote median / heartbeat-armed
    // remote median, measured in the same run (1.0 = heartbeats free)
    ratio_pairs
        .push(("remote_b1_heartbeat_vs_plain", num(remote_hb_ratio)));
    let jratios = obj(ratio_pairs);

    let report = obj(vec![
        ("bench", s("inference")),
        ("models", Json::Arr(jmodels)),
        ("kernel_micro", jkernel),
        ("serve_ab", serve_json),
        ("router_fleet", fleet_json),
        ("remote_loopback", remote_json),
        ("aq_configs", jaq),
        ("v3_ab", jv3),
        ("ratios", jratios),
        ("all_runs", b.report_json()),
        (
            "note",
            s("median_ns per forward call; throughput = batch / median; \
               v1 = PR-1 engine, v2 = tiled/fused/arena engine, \
               v3 = LUT2 integer-index engine (ratios are absolute \
               speedup factors, v2 median / v3 median)"),
        ),
    ]);
    std::fs::write("BENCH_inference.json", report.to_string())
        .expect("writing BENCH_inference.json");
    println!("[written] BENCH_inference.json");
    b.finish();
}
