//! Native inference benchmarks: LUT kernels vs dequantized-f32 vs the
//! PJRT eval step, at serving batch sizes 1 / 8 / 64. Emits
//! `BENCH_inference.json` (machine-readable, `util::bench` stats).
//!
//! Runs everywhere: models are synthetic UNIQ-frozen replicas of the AOT
//! variants; the PJRT column appears only when artifacts and a real xla
//! backend are present (recorded as null otherwise, with the reason).

use std::path::Path;

use uniq::coordinator::FreezeQuant;
use uniq::data::synth::{SynthConfig, SynthDataset};
use uniq::data::Batcher;
use uniq::infer::{synthetic, FrozenModel, KernelMode, ServeModel};
use uniq::util::bench::Bench;
use uniq::util::json::{num, obj, s, Json};

// 32 is the AOT variants' native batch — the only size the fixed-batch
// PJRT executables can be compared at.
const BATCHES: [usize; 4] = [1, 8, 32, 64];

fn main() {
    let mut b = Bench::quick("inference");
    b.min_time = std::time::Duration::from_millis(400);
    let data = SynthDataset::generate(SynthConfig {
        n: 64,
        ..Default::default()
    });
    let probe = Batcher::eval_batches(&data, 64).remove(0);

    let mut jmodels = Vec::new();
    for (name, width) in [("mobilenet_mini", 16usize), ("mlp", 16)] {
        let (m, state) = synthetic::model(name, width, 10, 7).unwrap();
        let frozen =
            FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
                .unwrap();
        let sm = ServeModel::new(frozen).unwrap();
        let mut jbatches = Vec::new();
        for batch in BATCHES {
            let x = &probe.x[..batch * data.image_len()];
            let lut = b.run_throughput(
                &format!("{name}/lut/b{batch}"),
                batch,
                || {
                    sm.graph
                        .forward(
                            &sm.model,
                            &sm.weights,
                            x,
                            batch,
                            KernelMode::Lut,
                        )
                        .unwrap()
                },
            );
            let deq = b.run_throughput(
                &format!("{name}/dequant_f32/b{batch}"),
                batch,
                || {
                    sm.graph
                        .forward(
                            &sm.model,
                            &sm.weights,
                            x,
                            batch,
                            KernelMode::DequantF32,
                        )
                        .unwrap()
                },
            );
            let pjrt = uniq::runtime::bench_eval_step(
                &mut b,
                &Path::new("artifacts").join(name),
                batch,
                x,
            );
            jbatches.push(obj(vec![
                ("batch", num(batch as f64)),
                ("lut", lut.to_json()),
                ("dequant_f32", deq.to_json()),
                ("pjrt", pjrt.map(|p| p.to_json()).unwrap_or(Json::Null)),
                ("lut_vs_f32_speedup", num(deq.median_ns / lut.median_ns)),
            ]));
        }
        jmodels.push(obj(vec![
            ("model", s(name)),
            ("bits_w", num(4.0)),
            ("batches", Json::Arr(jbatches)),
        ]));
    }

    let report = obj(vec![
        ("bench", s("inference")),
        ("models", Json::Arr(jmodels)),
        ("all_runs", b.report_json()),
        (
            "note",
            s("median_ns per forward call; throughput = batch / median"),
        ),
    ]);
    std::fs::write("BENCH_inference.json", report.to_string())
        .expect("writing BENCH_inference.json");
    println!("[written] BENCH_inference.json");
    b.finish();
}
