//! End-to-end step benchmarks over the AOT executables (requires
//! `make artifacts`; exits with a notice otherwise).
//!
//! Covers the hot path of every experiment harness: train step (the
//! noise-injection path), eval step, the generic-quantizer step (Table 3
//! overhead), the host freeze, and the literal-marshalling overhead that
//! the coordinator adds around the XLA execution.

use std::path::Path;

use uniq::coordinator::{FreezeQuant, Trainer};
use uniq::data::synth::{SynthConfig, SynthDataset};
use uniq::data::Batcher;
use uniq::runtime::state::StepConfig;
use uniq::runtime::Engine;
use uniq::util::bench::Bench;

fn main() {
    if !Path::new("artifacts/resnet8/train_step.hlo.txt").exists() {
        eprintln!("SKIP train_step bench: run `make artifacts` first");
        return;
    }
    let engine = Engine::cpu().expect("pjrt cpu client");
    let mut b = Bench::new("train_step");
    b.min_time = std::time::Duration::from_secs(3);

    let data = SynthDataset::generate(SynthConfig {
        n: 64,
        ..Default::default()
    });

    for variant in ["mlp", "resnet8", "resnet8_generic"] {
        let mut t =
            Trainer::new(&engine, &Path::new("artifacts").join(variant))
                .expect("trainer");
        let batch = Batcher::eval_batches(&data, t.manifest.batch).remove(0);
        let n = t.manifest.n_qlayers();
        let generic = t.manifest.noise_cfg == "generic";
        let cfg = StepConfig {
            lr: 1e-4,
            k_w: 8.0,
            k_a: 256.0,
            aq: 0.0,
            seed: 1,
            mode_vec: vec![1.0; n],
            qthresh: generic.then(|| {
                FreezeQuant::Uniform
                    .uniformized_thresholds(8, t.manifest.kmax)
            }),
        };
        b.run(&format!("{variant}/train_step"), || {
            t.step(&batch.x, &batch.y, &cfg).expect("step")
        });
        b.run(&format!("{variant}/eval_step_batch"), || {
            t.eval_batch(&batch.x, &batch.y, 256.0, 1.0).expect("eval")
        });
        // coordinator-side marshalling only (no XLA execution)
        b.run(&format!("{variant}/literal_marshalling"), || {
            t.state
                .train_inputs(&t.manifest, &batch.x, &batch.y, &cfg)
                .expect("inputs")
        });
        // host freeze of the biggest layer
        let m = t.manifest.clone();
        let big = (0..n)
            .max_by_key(|&q| t.state.qlayer_weights(&m, q).unwrap().len())
            .unwrap();
        b.run(&format!("{variant}/freeze_biggest_layer"), || {
            t.freeze_layer(big, FreezeQuant::KQuantileGauss, 16).unwrap()
        });
    }

    b.finish();
}
