//! Data-pipeline benchmarks: the producer side of the training loop.
//!
//! Target: batch assembly + augmentation must stay well under the
//! train-step latency (~100 ms for resnet8) so the double-buffered
//! prefetcher hides it completely.

use uniq::data::augment::{augment_train, hflip, pad_crop};
use uniq::data::batcher::Prefetcher;
use uniq::data::synth::{SynthConfig, SynthDataset};
use uniq::data::Batcher;
use uniq::util::bench::Bench;
use uniq::util::rng::Rng;

fn main() {
    let mut b = Bench::new("data_pipeline");

    b.run("synth/generate_1k_images", || {
        SynthDataset::generate(SynthConfig {
            n: 1000,
            ..Default::default()
        })
    });

    let data = SynthDataset::generate(SynthConfig {
        n: 4096,
        ..Default::default()
    });
    let img: Vec<f32> = data.image(0).to_vec();
    let mut rng = Rng::new(3);
    b.run_throughput("augment/pad_crop", 3072, || {
        pad_crop(&img, 32, 32, 3, 4, &mut rng)
    });
    let mut buf = img.clone();
    b.run_throughput("augment/hflip", 3072, || hflip(&mut buf, 32, 32, 3));
    b.run_throughput("augment/full", 3072, || {
        augment_train(&img, 32, 32, 3, &mut rng)
    });

    let mut batcher = Batcher::new(data.clone(), 32, true, 1);
    b.run_throughput("batcher/next_batch_32_augmented", 32 * 3072, || {
        batcher.next_batch()
    });
    let mut plain = Batcher::new(data.clone(), 32, false, 1);
    b.run_throughput("batcher/next_batch_32_plain", 32 * 3072, || {
        plain.next_batch()
    });

    // prefetcher steady-state (consumer-side latency once the thread is
    // ahead: should be near-zero channel receive time)
    let pf = Prefetcher::new(Batcher::new(data, 32, true, 2), 2);
    pf.next_batch(); // let the producer spin up
    b.run("prefetcher/steady_state_recv", || pf.next_batch());

    b.finish();
}
