//! v3 (LUT²) engine suite — the `--engine v3` column of the CI
//! bitwidth matrix (`UNIQ_AQ_MODE`/`UNIQ_AQ_BITS` select one cell, a
//! plain `cargo test` covers both aq families at 4 bits).
//!
//! Gates:
//!   * the full weight-bits × activation-bits matrix
//!     (w ∈ {1,2,3,4,5,8} × a ∈ {2,4,8}; 5 exercises the generic
//!     non-power-of-two PackedBits gather) keeps v3 **bit-identical**
//!     to v2 and ≤ 1e-5 from the dequant-f32 reference on all three
//!     architectures;
//!   * edge typing is structural: f32 seams exactly where the plan
//!     says (image input, post-pool, downsample branch), QIdx
//!     everywhere a table feeds a GEMM, product tables resident for
//!     exactly the QIdx edges;
//!   * v3 without aq tables is refused, and a live edge with a stale
//!     working set (tables installed after weight prep, no refresh)
//!     errors naming `prepare_v3` instead of serving garbage;
//!   * steady-state v3 serving performs zero heap allocation (arena
//!     fingerprint, including the u16 qpatches buffer);
//!   * `ServeConfig { mode: LutV3 }` serves end-to-end — directly and
//!     through the replica-set router — bit-identical to v2 replies.

use std::sync::Arc;
use std::time::Duration;

use uniq::coordinator::FreezeQuant;
use uniq::infer::{
    actquant, kernels, synthetic, AqMode, EdgeType, ExecBuffers,
    FrozenModel, Graph, KernelMode, PreparedWeights, Router,
    RouterConfig, RoutingPolicy, ServeConfig, ServeModel, Server,
};
use uniq::util::rng::Rng;

const ARCHS: [(&str, usize); 3] =
    [("mlp", 12), ("resnet8", 8), ("mobilenet_mini", 8)];

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() * 0.2).collect()
}

/// The aq cells this process covers (same contract as infer_aq.rs):
/// one cell under the CI matrix env vars, both modes at 4 bits for a
/// plain local `cargo test`.
fn matrix_cfgs() -> Vec<(AqMode, u32)> {
    let bits = std::env::var("UNIQ_AQ_BITS")
        .ok()
        .and_then(|v| v.parse::<u32>().ok())
        .unwrap_or(4);
    match std::env::var("UNIQ_AQ_MODE") {
        Ok(m) => vec![(
            AqMode::parse(&m)
                .expect("UNIQ_AQ_MODE")
                .expect("UNIQ_AQ_MODE must not be 'none'"),
            bits,
        )],
        Err(_) => vec![(AqMode::Uniform, bits), (AqMode::Quantile, bits)],
    }
}

/// Frozen synthetic model at `bits_w` weight bits, optionally aq
/// calibrated — with the v3 working set refreshed after the tables
/// land (the step `ServeModel::calibrate_aq` performs in production).
fn built(
    name: &str,
    width: usize,
    bits_w: u32,
    aq: Option<(AqMode, u32)>,
) -> (FrozenModel, Graph, PreparedWeights) {
    let (m, state) = synthetic::model(name, width, 10, 29).unwrap();
    let mut frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, bits_w)
            .unwrap();
    let graph = Graph::from_model(&frozen).unwrap();
    let mut weights = PreparedWeights::new(&frozen, &graph);
    if let Some((mode, bits)) = aq {
        let img_len: usize = frozen.image.iter().product();
        let calib = randvec(12 * img_len, 97);
        frozen.aq = Some(
            actquant::calibrate(
                &frozen, &graph, &weights, &calib, 6, mode, bits,
            )
            .unwrap(),
        );
        weights.prepare_v3(&frozen, &graph);
    }
    (frozen, graph, weights)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

/// The bitwidth-pair matrix: every (b_w, b_a) cell on every arch keeps
/// v3 bit-identical to v2 and within 1e-5 of the f32 reference. The aq
/// mode alternates per cell so both families appear in every run.
#[test]
fn v3_bitwidth_matrix_bit_identical_to_v2_all_archs() {
    let w_bits = [1u32, 2, 3, 4, 5, 8];
    let a_bits = [2u32, 4, 8];
    for (ci, &bw) in w_bits.iter().enumerate() {
        for (cj, &ba) in a_bits.iter().enumerate() {
            let mode = if (ci + cj) % 2 == 0 {
                AqMode::Quantile
            } else {
                AqMode::Uniform
            };
            for (name, width) in ARCHS {
                let (frozen, graph, weights) =
                    built(name, width, bw, Some((mode, ba)));
                let img_len: usize = frozen.image.iter().product();
                let x = randvec(2 * img_len, 11 + bw as u64 * 10 + ba as u64);
                let v2 = graph
                    .forward(&frozen, &weights, &x, 2, KernelMode::Lut)
                    .unwrap();
                let v3 = graph
                    .forward(&frozen, &weights, &x, 2, KernelMode::LutV3)
                    .unwrap();
                assert_eq!(
                    v3, v2,
                    "{name} w{bw}a{ba} {mode:?}: v3 drifted from v2"
                );
                let refr = graph
                    .forward(
                        &frozen, &weights, &x, 2, KernelMode::DequantF32,
                    )
                    .unwrap();
                let d = max_abs_diff(&v3, &refr);
                assert!(
                    d <= 1e-5,
                    "{name} w{bw}a{ba} {mode:?}: v3 vs f32 diff {d}"
                );
                assert!(v3.iter().all(|v| v.is_finite()));
            }
        }
    }
}

/// Edge typing is structural, not incidental: on an aq-calibrated
/// mobilenet the first conv (f32 image) and the classifier (post-pool)
/// are F32 seams, every depthwise/pointwise GEMM is a QIdx edge, and
/// the v3 working set is resident for exactly the QIdx-fed layers.
#[test]
fn v3_edge_typing_marks_seams_and_builds_tables() {
    for (mode, bits) in matrix_cfgs() {
        let (frozen, graph, weights) =
            built("mobilenet_mini", 8, 4, Some((mode, bits)));
        let edges = graph.gemm_edges(&frozen);
        assert_eq!(edges.len(), frozen.layers.len());
        let fc = frozen.layer_index("fc").unwrap();
        let conv1 = frozen.layer_index("conv1").unwrap();
        let mut qidx_layers = Vec::new();
        for &(q, et) in &edges {
            match et {
                EdgeType::F32 => assert!(
                    q == fc || q == conv1,
                    "{}: unexpected f32 seam",
                    frozen.layers[q].name
                ),
                EdgeType::QIdx { src, bits: b } => {
                    assert_eq!(b as u32, bits);
                    assert!(
                        frozen.aq.as_ref().unwrap().table(src).is_some(),
                        "QIdx edge from a table-less source"
                    );
                    qidx_layers.push(q);
                }
            }
        }
        assert_eq!(
            qidx_layers.len(),
            frozen.layers.len() - 2,
            "every GEMM between the seams rides the index stream"
        );
        for (q, v3) in weights.v3.iter().enumerate() {
            assert_eq!(
                v3.is_some(),
                qidx_layers.contains(&q),
                "{}: v3 working set vs edge type",
                frozen.layers[q].name
            );
            if let Some(v3) = v3 {
                let l = &frozen.layers[q];
                let k_w = l.codebook.len();
                let k_a = v3.stride - 1;
                assert!(k_w <= 256 && k_a <= 256);
                assert_eq!(v3.table.len(), k_w * v3.stride);
                assert_eq!(v3.table_bytes(), 4 * k_w * v3.stride);
                // the pad column is exactly zero
                for w in 0..k_w {
                    assert_eq!(v3.table[w * v3.stride + k_a], 0.0);
                }
                // depthwise gathers unpacked indices, GEMMs stream
                // packed transposed rows
                let dw = l.name.ends_with("/dw");
                assert_eq!(v3.widx.is_none(), dw, "{}", l.name);
            }
        }
        assert!(weights.v3_table_bytes() > 0);
        // resnet adds the third seam kind: the downsample branch reads
        // the saved pre-block tensor and must stay f32
        let (rfrozen, rgraph, _) =
            built("resnet8", 8, 4, Some((mode, bits)));
        let redges = rgraph.gemm_edges(&rfrozen);
        for &(q, et) in &redges {
            if rfrozen.layers[q].name.ends_with("/down") {
                assert_eq!(
                    et,
                    EdgeType::F32,
                    "downsample branch must be an f32 seam"
                );
            }
        }
        assert!(
            redges.iter().any(|&(_, et)| matches!(
                et,
                EdgeType::QIdx { .. }
            )),
            "resnet main path must have live QIdx edges"
        );
    }
}

/// `--engine v3` without aq tables is refused up front (there is no
/// index stream to consume), for both the direct forward and the
/// serving wrapper.
#[test]
fn v3_refused_without_aq_tables() {
    let (frozen, graph, weights) = built("mlp", 12, 4, None);
    let img_len: usize = frozen.image.iter().product();
    let x = randvec(img_len, 3);
    let err = graph
        .forward(&frozen, &weights, &x, 1, KernelMode::LutV3)
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("activation-quant"),
        "unhelpful refusal: {err}"
    );
}

/// Installing tables after weight prep without refreshing the working
/// set is the one way the v3 invariant can break — the executor must
/// error naming the fix, not fall back silently.
#[test]
fn v3_stale_working_set_errors_naming_prepare_v3() {
    let (mode, bits) = matrix_cfgs()[0];
    let (mut frozen, graph, mut weights) = built("mlp", 12, 4, None);
    let img_len: usize = frozen.image.iter().product();
    let calib = randvec(8 * img_len, 13);
    frozen.aq = Some(
        actquant::calibrate(
            &frozen, &graph, &weights, &calib, 4, mode, bits,
        )
        .unwrap(),
    );
    let x = randvec(img_len, 17);
    let err = graph
        .forward(&frozen, &weights, &x, 1, KernelMode::LutV3)
        .unwrap_err()
        .to_string();
    assert!(err.contains("prepare_v3"), "unhelpful error: {err}");
    // the named fix works
    weights.prepare_v3(&frozen, &graph);
    let v3 = graph
        .forward(&frozen, &weights, &x, 1, KernelMode::LutV3)
        .unwrap();
    let v2 = graph
        .forward(&frozen, &weights, &x, 1, KernelMode::Lut)
        .unwrap();
    assert_eq!(v3, v2);
}

/// Steady-state v3 execution reuses the arena verbatim — the
/// zero-allocation contract extends to the index stream and the u16
/// quantized-patch buffer.
#[test]
fn v3_serving_keeps_the_arena_allocation_free() {
    for (mode, bits) in matrix_cfgs() {
        let (frozen, graph, _full) =
            built("mobilenet_mini", 8, 4, Some((mode, bits)));
        let weights = PreparedWeights::lut_only(&frozen, &graph);
        let img_len: usize = frozen.image.iter().product();
        let batch = 4usize;
        let x = randvec(batch * img_len, 37);
        let mut bufs = ExecBuffers::new();
        for _ in 0..2 {
            graph
                .forward_into(
                    &frozen, &weights, &x, batch, KernelMode::LutV3,
                    &mut bufs,
                )
                .unwrap();
        }
        let fp = bufs.arena_fingerprint();
        for _ in 0..4 {
            graph
                .forward_into(
                    &frozen, &weights, &x, batch, KernelMode::LutV3,
                    &mut bufs,
                )
                .unwrap();
        }
        assert_eq!(
            bufs.arena_fingerprint(),
            fp,
            "{mode:?}{bits}: v3 arena reallocated in steady state"
        );
    }
}

/// `ServeConfig { mode: LutV3 }` end to end: calibrate through the
/// serving wrapper (which refreshes the v3 working set), serve a
/// batch, and match both the direct v3 forward and the v2 engine
/// bit-for-bit.
#[test]
fn v3_serves_end_to_end_matching_v2() {
    let (m, state) = synthetic::model("mobilenet_mini", 8, 10, 53).unwrap();
    let frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    let mut sm = ServeModel::new(frozen).unwrap();
    let img_len = sm.image_len();
    let calib = randvec(12 * img_len, 59);
    sm.calibrate_aq(AqMode::Quantile, 4, &calib, 6).unwrap();
    assert!(
        sm.weights.v3_table_bytes() > 0,
        "calibrate_aq must refresh the v3 working set"
    );
    let sm = Arc::new(sm);
    let srv = Server::start(
        Arc::clone(&sm),
        ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            mode: KernelMode::LutV3,
            kernel_threads: 1,
            shed_after: None,
        },
    );
    let images: Vec<Vec<f32>> =
        (0..9).map(|i| randvec(img_len, 70 + i as u64)).collect();
    let handles: Vec<_> = images
        .iter()
        .map(|img| srv.submit(img.clone()).unwrap())
        .collect();
    for (img, h) in images.iter().zip(handles) {
        let reply = h.recv().expect("reply");
        let v3 = sm
            .graph
            .forward(&sm.model, &sm.weights, img, 1, KernelMode::LutV3)
            .unwrap();
        let v2 = sm
            .graph
            .forward(&sm.model, &sm.weights, img, 1, KernelMode::Lut)
            .unwrap();
        assert_eq!(reply.logits, v3, "served v3 logits drifted");
        assert_eq!(v3, v2, "v3 != v2 through the serving tier");
        assert_eq!(reply.pred, kernels::argmax(&v3));
    }
    assert_eq!(srv.shutdown().requests, 9);
}

/// The replica-set router threads `--engine v3` through every replica:
/// routed replies stay bit-identical to the direct v3 forward.
#[test]
fn v3_through_replica_router_bitwise() {
    let (m, state) = synthetic::model("mlp", 16, 10, 61).unwrap();
    let frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    let mut sm = ServeModel::new(frozen).unwrap();
    let img_len = sm.image_len();
    let calib = randvec(10 * img_len, 67);
    sm.calibrate_aq(AqMode::Uniform, 4, &calib, 5).unwrap();
    let sm = Arc::new(sm);
    let router = Router::start(
        Arc::clone(&sm),
        RouterConfig {
            replicas: 2,
            policy: RoutingPolicy::RoundRobin,
            queue_cap: 1024,
            health_every: Duration::ZERO,
            max_retries: 8,
            seed: 11,
            request_timeout: None,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            serve: ServeConfig {
                workers: 1,
                max_batch: 16,
                max_wait: Duration::from_millis(1),
                mode: KernelMode::LutV3,
                kernel_threads: 1,
                shed_after: None,
            },
        },
    );
    let images: Vec<Vec<f32>> =
        (0..8).map(|i| randvec(img_len, 80 + i as u64)).collect();
    let pending: Vec<_> = (0..16)
        .map(|i| (i, router.submit(&images[i % images.len()]).unwrap()))
        .collect();
    for (i, p) in pending {
        let reply = p.recv().unwrap();
        let want = sm
            .graph
            .forward(
                &sm.model,
                &sm.weights,
                &images[i % images.len()],
                1,
                KernelMode::LutV3,
            )
            .unwrap();
        assert_eq!(reply.logits, want, "request {i}: routed v3 drifted");
    }
    let fleet = router.shutdown();
    assert_eq!(fleet.fleet.requests, 16);
    assert_eq!(fleet.rejected, 0);
}
