//! Replica-set router integration tests, plus the CI soak.
//!
//! The fast tests run in the tier-1 gate (`cargo test -q`). The soak —
//! ≥1k requests across 3 replicas with one replica killed mid-run,
//! asserting zero dropped requests and bit-identical outputs vs a
//! single-replica run — is `#[ignore]`d and driven explicitly by the CI
//! bench job:
//!
//!     cargo test --release -q --test serve_router -- soak --ignored

use std::sync::Arc;
use std::time::Duration;

use uniq::coordinator::FreezeQuant;
use uniq::infer::{
    synthetic, FleetStats, FrozenModel, KernelMode, Router, RouterConfig,
    RoutingPolicy, ServeConfig, ServeModel, SubmitError,
};
use uniq::util::rng::Rng;

fn model() -> Arc<ServeModel> {
    let (m, st) = synthetic::mlp(32, 10, 7);
    let frozen =
        FrozenModel::export(&m, &st, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    Arc::new(ServeModel::new(frozen).unwrap())
}

fn router_cfg(
    replicas: usize,
    policy: RoutingPolicy,
    queue_cap: usize,
    max_wait: Duration,
) -> RouterConfig {
    RouterConfig {
        replicas,
        policy,
        queue_cap,
        // tests drive heal_now() themselves for determinism; the soak
        // overrides this to exercise the background monitor
        health_every: Duration::ZERO,
        max_retries: 8,
        seed: 11,
        request_timeout: None,
        breaker_threshold: 3,
        breaker_cooldown: Duration::from_millis(250),
        serve: ServeConfig {
            workers: 1,
            max_batch: 16,
            max_wait,
            mode: KernelMode::Lut,
            kernel_threads: 1,
            shed_after: None,
        },
    }
}

fn images(sm: &ServeModel, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let img_len = sm.image_len();
    (0..n)
        .map(|_| (0..img_len).map(|_| rng.normal()).collect())
        .collect()
}

/// Round-robin rotates the cursor per submit: 30 requests over 3 live
/// replicas land exactly 10/10/10.
#[test]
fn round_robin_spreads_traffic_exactly() {
    let sm = model();
    let router = Router::start(
        Arc::clone(&sm),
        router_cfg(
            3,
            RoutingPolicy::RoundRobin,
            1024,
            Duration::from_millis(1),
        ),
    );
    let imgs = images(&sm, 6, 3);
    let pending: Vec<_> = (0..30)
        .map(|i| router.submit(&imgs[i % imgs.len()]).unwrap())
        .collect();
    for p in pending {
        p.recv().unwrap();
    }
    let fleet = router.shutdown();
    assert_eq!(fleet.fleet.requests, 30);
    let routed: Vec<usize> =
        fleet.replicas.iter().map(|r| r.routed).collect();
    assert_eq!(routed, vec![10, 10, 10], "round-robin must spread exactly");
    assert_eq!(fleet.restarts, 0);
    assert_eq!(fleet.resubmits, 0);
    assert_eq!(fleet.rejected, 0);
}

/// Every routed reply is bit-identical to a direct single-image forward
/// — the replica set inherits the PR-3 determinism invariant.
#[test]
fn routed_replies_match_direct_forward_bitwise() {
    let sm = model();
    // wide collector window: all 24 submits land before anything is
    // served, so least-outstanding's 12/12 split is deterministic
    let router = Router::start(
        Arc::clone(&sm),
        router_cfg(
            2,
            RoutingPolicy::LeastOutstanding,
            1024,
            Duration::from_millis(150),
        ),
    );
    let imgs = images(&sm, 12, 5);
    let pending: Vec<_> = (0..24)
        .map(|i| (i, router.submit(&imgs[i % imgs.len()]).unwrap()))
        .collect();
    for (i, p) in pending {
        let reply = p.recv().unwrap();
        let want = sm
            .graph
            .forward(
                &sm.model,
                &sm.weights,
                &imgs[i % imgs.len()],
                1,
                KernelMode::Lut,
            )
            .unwrap();
        assert_eq!(reply.logits, want, "request {i}: logits drifted");
        assert_eq!(reply.pred, uniq::infer::kernels::argmax(&want));
    }
    let fleet = router.shutdown();
    assert_eq!(fleet.fleet.requests, 24);
    // least-outstanding over sequential submits spreads evenly
    let routed: Vec<usize> =
        fleet.replicas.iter().map(|r| r.routed).collect();
    assert_eq!(routed, vec![12, 12]);
}

/// Saturating every replica's outstanding cap rejects with the typed
/// `Overloaded` error — and the fleet recovers once replies drain.
#[test]
fn backpressure_rejects_typed_then_recovers() {
    let sm = model();
    // long collector wait: submitted requests stay outstanding while
    // the test probes the cap deterministically
    let router = Router::start(
        Arc::clone(&sm),
        router_cfg(
            3,
            RoutingPolicy::LeastOutstanding,
            4,
            Duration::from_millis(300),
        ),
    );
    let imgs = images(&sm, 1, 9);
    let mut pending = Vec::new();
    for _ in 0..12 {
        pending.push(router.submit(&imgs[0]).unwrap());
    }
    assert_eq!(router.outstanding(), 12, "3 replicas x cap 4 all filled");
    match router.submit(&imgs[0]) {
        Err(SubmitError::Overloaded { outstanding, cap }) => {
            assert_eq!(cap, 4);
            assert_eq!(outstanding, 4, "least-loaded replica is at cap");
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    // drain: after every reply lands, capacity is back
    for p in pending {
        p.recv().unwrap();
    }
    assert_eq!(router.outstanding(), 0);
    let p = router.submit(&imgs[0]).expect("capacity back after drain");
    p.recv().unwrap();
    let fleet = router.shutdown();
    assert_eq!(fleet.fleet.requests, 13);
    assert_eq!(fleet.rejected, 1, "exactly one typed rejection");
}

/// Health sweep replaces a killed replica with a fresh generation; the
/// dead generation's served stats survive into the fleet merge.
#[test]
fn killed_replica_restarts_and_history_survives() {
    let sm = model();
    let router = Router::start(
        Arc::clone(&sm),
        router_cfg(
            2,
            RoutingPolicy::RoundRobin,
            1024,
            Duration::from_millis(1),
        ),
    );
    let imgs = images(&sm, 4, 17);
    // phase 1: both replicas serve
    let pending: Vec<_> = (0..8)
        .map(|i| router.submit(&imgs[i % imgs.len()]).unwrap())
        .collect();
    for p in pending {
        p.recv().unwrap();
    }
    assert_eq!(router.alive_count(), 2);
    router.kill_replica(0);
    assert_eq!(router.alive_count(), 1, "killed replica must read dead");
    router.heal_now();
    assert_eq!(router.alive_count(), 2, "heal must install a fresh gen");
    assert_eq!(router.restarts(), 1);
    // phase 2: traffic flows through the healed fleet
    let pending: Vec<_> = (0..8)
        .map(|i| router.submit(&imgs[i % imgs.len()]).unwrap())
        .collect();
    for p in pending {
        p.recv().unwrap();
    }
    let fleet = router.shutdown();
    assert_eq!(
        fleet.fleet.requests, 16,
        "dead generation's serves must survive into the fleet merge"
    );
    assert_eq!(fleet.restarts, 1);
    assert_eq!(fleet.replicas[0].generation, 1, "replica 0 was restarted");
    assert_eq!(fleet.replicas[1].generation, 0);
    assert_eq!(fleet.lost_in_flight, 0, "no requests were in flight");
}

/// A replica killed WITH requests queued: the clients' `Pending::recv`
/// observes the dropped channels and resubmits through the router —
/// every request still gets a (bit-correct) reply.
#[test]
fn inflight_kill_resubmits_with_zero_drops() {
    let sm = model();
    // long collector wait so the first wave is still queued at the kill
    let router = Router::start(
        Arc::clone(&sm),
        router_cfg(
            2,
            RoutingPolicy::LeastOutstanding,
            1024,
            Duration::from_millis(300),
        ),
    );
    let imgs = images(&sm, 8, 21);
    let pending: Vec<_> = (0..8)
        .map(|i| (i, router.submit(&imgs[i]).unwrap()))
        .collect();
    // 4 queued on each replica; replica 0 dies with its queue intact
    router.kill_replica(0);
    router.heal_now();
    assert_eq!(router.restarts(), 1);
    for (i, p) in pending {
        let reply = p.recv().unwrap_or_else(|e| {
            panic!("request {i} dropped across the kill: {e}")
        });
        let want = sm
            .graph
            .forward(&sm.model, &sm.weights, &imgs[i], 1, KernelMode::Lut)
            .unwrap();
        assert_eq!(reply.logits, want, "request {i}: logits drifted");
    }
    let fleet = router.shutdown();
    assert_eq!(fleet.fleet.requests, 8, "every request served exactly once");
    assert_eq!(
        fleet.lost_in_flight, 4,
        "replica 0's queued wave was lost with the kill"
    );
    assert_eq!(fleet.resubmits, 4, "and resubmitted by its Pendings");
}

/// Power-of-two-choices: all requests served, policy touches more than
/// one replica (a deterministic sampler property, seeded in the config).
#[test]
fn power_of_two_serves_all_requests() {
    let sm = model();
    let router = Router::start(
        Arc::clone(&sm),
        router_cfg(
            3,
            RoutingPolicy::PowerOfTwo,
            1024,
            Duration::from_millis(1),
        ),
    );
    let imgs = images(&sm, 10, 31);
    let pending: Vec<_> = (0..60)
        .map(|i| router.submit(&imgs[i % imgs.len()]).unwrap())
        .collect();
    for p in pending {
        p.recv().unwrap();
    }
    let fleet = router.shutdown();
    assert_eq!(fleet.fleet.requests, 60);
    let routed: Vec<usize> =
        fleet.replicas.iter().map(|r| r.routed).collect();
    assert_eq!(routed.iter().sum::<usize>(), 60);
    assert!(
        routed.iter().filter(|&&r| r > 0).count() >= 2,
        "p2c must spread over more than one replica, got {routed:?}"
    );
}

fn run_traffic(
    sm: &Arc<ServeModel>,
    imgs: &[Vec<f32>],
    n: usize,
    replicas: usize,
    kill_at: Option<usize>,
) -> (Vec<Vec<f32>>, FleetStats) {
    let router = Router::start(
        Arc::clone(sm),
        RouterConfig {
            replicas,
            policy: RoutingPolicy::PowerOfTwo,
            queue_cap: 8192,
            // the soak exercises the REAL health path: the background
            // monitor must notice the kill and restart the replica
            health_every: Duration::from_millis(3),
            max_retries: 8,
            seed: 29,
            request_timeout: None,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            serve: ServeConfig {
                workers: 1,
                max_batch: 16,
                max_wait: Duration::from_millis(1),
                mode: KernelMode::Lut,
                kernel_threads: 1,
                shed_after: None,
            },
        },
    );
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        if Some(i) == kill_at {
            router.kill_replica(1);
        }
        pending.push(router.submit(&imgs[i % imgs.len()]).expect("submit"));
    }
    let logits: Vec<Vec<f32>> = pending
        .into_iter()
        .enumerate()
        .map(|(i, p)| {
            p.recv()
                .unwrap_or_else(|e| panic!("request {i} dropped: {e}"))
                .logits
        })
        .collect();
    (logits, router.shutdown())
}

/// The CI soak: 1200 requests across 3 replicas, replica 1 killed at
/// the halfway submit with its queue full, automatic (monitor-driven)
/// restart, zero dropped requests, outputs bit-identical to a
/// single-replica run of the same traffic.
#[test]
#[ignore = "soak: run explicitly (CI bench job) with -- soak --ignored"]
fn soak_kill_one_replica_mid_run_zero_drops() {
    let sm = model();
    let n = 1200;
    let imgs = images(&sm, 48, 13);
    let (expected, single) = run_traffic(&sm, &imgs, n, 1, None);
    assert_eq!(single.fleet.requests, n);
    let (got, fleet) = run_traffic(&sm, &imgs, n, 3, Some(n / 2));
    assert_eq!(
        fleet.fleet.requests, n,
        "every request must be served exactly once across the kill"
    );
    assert!(
        fleet.restarts >= 1,
        "the health monitor never restarted the killed replica"
    );
    for (i, (a, b)) in expected.iter().zip(&got).enumerate() {
        assert_eq!(
            a, b,
            "request {i}: fleet output differs from single-replica run"
        );
    }
    println!(
        "soak: {} requests, {} restarts, {} resubmits, {} lost in flight \
         — zero drops, bit-identical",
        n, fleet.restarts, fleet.resubmits, fleet.lost_in_flight
    );
}
