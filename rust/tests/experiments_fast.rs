//! Fast experiment-harness integration: the analytic harnesses (table1,
//! fig1) run end to end and leave machine-readable results behind.
//! The training-based harnesses are exercised by `make experiments`
//! and asserted at the claim level in their unit tests.

use std::collections::HashMap;

use uniq::experiments;
use uniq::experiments::common::ExpCtx;

fn ctx() -> Option<ExpCtx> {
    let artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts.join("mlp/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(ExpCtx::new(artifacts, HashMap::new()).unwrap())
}

#[test]
fn table1_and_fig1_regenerate() {
    let Some(ctx) = ctx() else { return };
    experiments::run("table1", &ctx).unwrap();
    experiments::run("fig1", &ctx).unwrap();
    let t1 = std::fs::read_to_string("results/table1.tsv").unwrap();
    // all 31 rows + header
    assert_eq!(t1.lines().count(), 32);
    // spot-check one row: UNIQ mobilenet (4,8) -> 16.8 Mbit
    let row = t1
        .lines()
        .find(|l| l.starts_with("mobilenet\tUNIQ\t4\t8"))
        .expect("row missing");
    let mbit: f64 = row.split('\t').nth(4).unwrap().parse().unwrap();
    assert!((mbit - 16.8).abs() < 0.2, "{row}");

    let f1 = std::fs::read_to_string("results/fig1.tsv").unwrap();
    assert!(f1.lines().count() >= 32);
    let plot = std::fs::read_to_string("results/fig1.txt").unwrap();
    assert!(plot.contains('U') && plot.contains('B'));
}

#[test]
fn unknown_experiment_is_an_error() {
    let Some(ctx) = ctx() else { return };
    assert!(experiments::run("tableZZ", &ctx).is_err());
}
