#!/usr/bin/env python3
"""Regenerate the pre-aq (format v1) frozen-model fixture.

The fixture freezes the PR-1..PR-4 on-disk format — no ``version`` key,
no ``act_quant`` section — so ``FrozenModel::load`` stays
backwards-compatible forever (rust/tests/infer_aq.rs loads and serves
it). Deterministic: every value is an exact binary fraction, so the
JSON→f32 roundtrip is lossless and the expected logits printed at the
end are stable.

Run from the repo root:
    python rust/tests/fixtures/make_pre_aq_fixture.py
"""
import json
import struct
from pathlib import Path

OUT = Path(__file__).parent / "pre_aq_frozen"

# tiny MLP the name-driven graph builder recognises: fc1 [12,6] -> relu
# -> fc2 [6,4]; image [2,2,3] (12 features), 4 classes, 2-bit codebooks
CB1 = [-1.5, -0.5, 0.5, 1.5]
CB2 = [-1.0, -0.25, 0.25, 1.0]
IDX1 = [(i * 3 + 1) % 4 for i in range(12 * 6)]
IDX2 = [(i * 5 + 2) % 4 for i in range(6 * 4)]
B1 = [0.125 * i - 0.25 for i in range(6)]
B2 = [-0.5, 0.25, 0.0, 0.75]


def pack2(vals):
    """LSB-first 2-bit packing (infer::packed::PackedBits layout)."""
    data = bytearray((len(vals) * 2 + 7) // 8)
    for i, v in enumerate(vals):
        byte, off = divmod(i * 2, 8)
        data[byte] |= (v & 3) << off
    return bytes(data)


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    blob = bytearray()
    layers = []
    for name, shape, idx, cb in [
        ("fc1", [12, 6], IDX1, CB1),
        ("fc2", [6, 4], IDX2, CB2),
    ]:
        off = len(blob)
        blob += pack2(idx)
        layers.append(
            dict(name=name, shape=shape, bits=2, n=len(idx), offset=off,
                 codebook=cb)
        )
    params = []
    for name, data in [("fc1/b", B1), ("fc2/b", B2)]:
        off = len(blob)
        for v in data:
            blob += struct.pack("<f", v)
        params.append(
            dict(name=name, shape=[len(data)], offset=off, size=len(data))
        )
    meta = dict(
        name="pre_aq_mlp",
        image=[2, 2, 3],
        classes=4,
        bits_w=2,
        layers=layers,
        params=params,
        state=[],
    )
    (OUT / "frozen.json").write_text(json.dumps(meta))
    (OUT / "frozen.bin").write_bytes(bytes(blob))

    # expected logits for the deterministic probe input (exact /8
    # fractions; see infer_aq.rs::pre_aq_fixture_loads_and_serves)
    x = [((i * 7) % 13) / 8.0 - 0.5 for i in range(12)]
    w1 = [[CB1[IDX1[j * 6 + o]] for o in range(6)] for j in range(12)]
    w2 = [[CB2[IDX2[j * 4 + o]] for o in range(4)] for j in range(6)]
    h = [max(sum(x[j] * w1[j][o] for j in range(12)) + B1[o], 0.0)
         for o in range(6)]
    y = [sum(h[j] * w2[j][o] for j in range(6)) + B2[o] for o in range(4)]
    print("probe x:", x)
    print("expected logits:", y)
    print("argmax:", max(range(4), key=lambda i: y[i]))


if __name__ == "__main__":
    main()
