//! Golden parity: manifest → codebook export → LUT execution must match
//! the exact host quantizer (`Quantizer::quantize`) + f32 reference math
//! within 1e-5, end to end. Runs without AOT artifacts (synthetic
//! manifest-faithful models); when artifacts exist, the real
//! manifest/init.bin export is round-tripped too.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use uniq::coordinator::FreezeQuant;
use uniq::data::synth::{SynthConfig, SynthDataset};
use uniq::data::Batcher;
use uniq::infer::{
    kernels, synthetic, ExecBuffers, FrozenModel, Graph, KernelMode,
    PreparedWeights, ServeConfig, ServeModel, Server,
};
use uniq::quant::{KQuantileGauss, QuantizerFit};
use uniq::runtime::{Manifest, ModelState};
use uniq::util::rng::Rng;

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() * 0.2).collect()
}

/// The satellite golden test: codebook-exported LUT matmul vs
/// `Quantizer::quantize` + f32 reference matmul, ≤ 1e-5.
#[test]
fn lut_gemm_matches_exact_quantizer_reference() {
    let (rows, cin, cout) = (48usize, 96usize, 32usize);
    let x = randvec(rows * cin, 1);
    let w = randvec(cin * cout, 2);
    for k in [4usize, 8, 16, 256] {
        let q = KQuantileGauss.fit(&w, k);

        // reference: exact host freeze + plain f32 matmul
        let mut wq = w.clone();
        q.quantize(&mut wq);
        let mut want = vec![0.0f32; rows * cout];
        kernels::matmul_f32(&x, &wq, rows, cin, cout, &mut want);

        // LUT path: export through the codebook (bit-packed indices)
        let layer = uniq::infer::LayerCodebook::from_weights(
            "fc", &[cin, cout], &w, &q,
        );
        assert_eq!(layer.dequantize(), wq, "codebook expand != exact freeze");
        let idx_t = kernels::transpose_idx(&layer.indices.unpack(), cin, cout);
        let mut got = vec![0.0f32; rows * cout];
        kernels::lut_matmul(
            &x, &idx_t, &layer.codebook, rows, cin, cout, &mut got,
        );

        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5, "k={k}: {a} vs {b}");
        }
    }
}

/// Whole-graph parity on every synthetic architecture.
#[test]
fn graph_forward_lut_matches_f32_all_archs() {
    let data = SynthDataset::generate(SynthConfig {
        n: 8,
        ..Default::default()
    });
    let batch = Batcher::eval_batches(&data, 4).remove(0);
    for (name, width) in [("mlp", 16usize), ("resnet8", 8), ("mobilenet_mini", 16)] {
        let (m, state) = synthetic::model(name, width, 10, 11).unwrap();
        let frozen =
            FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
                .unwrap();
        let graph = Graph::from_model(&frozen).unwrap();
        let weights = PreparedWeights::new(&frozen, &graph);
        let lut = graph
            .forward(&frozen, &weights, &batch.x, batch.n, KernelMode::Lut)
            .unwrap();
        let refr = graph
            .forward(
                &frozen,
                &weights,
                &batch.x,
                batch.n,
                KernelMode::DequantF32,
            )
            .unwrap();
        assert_eq!(lut.len(), batch.n * 10, "{name}: logits shape");
        let max_diff = lut
            .iter()
            .zip(&refr)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff <= 1e-5, "{name}: LUT vs f32 diff {max_diff}");
        assert!(
            lut.iter().all(|v| v.is_finite()),
            "{name}: non-finite logits"
        );
    }
}

/// The v2 tiled/threaded LUT-GEMM is bit-identical to the v1 kernel,
/// to a single-threaded v2 run, and across repeated runs (the split
/// points are a pure function of (rows, threads), so a fixed config can
/// never produce two different outputs).
#[test]
fn threaded_lut_gemm_is_deterministic_and_matches_v1() {
    // big enough to clear the parallel work-size threshold
    let (rows, cin, cout) = (320usize, 72usize, 40usize);
    assert!(rows * cin * cout >= uniq::infer::kernels::GEMM_PAR_MIN_MACS);
    let x = randvec(rows * cin, 71);
    let w = randvec(cin * cout, 72);
    let q = KQuantileGauss.fit(&w, 16);
    let idx: Vec<u8> = w.iter().map(|&v| q.bin(v) as u8).collect();
    let idx_t = kernels::transpose_idx(&idx, cin, cout);

    let mut v1 = vec![0.0f32; rows * cout];
    kernels::lut_matmul(&x, &idx_t, &q.levels, rows, cin, cout, &mut v1);

    let mut single = vec![0.0f32; rows * cout];
    let mut pool = kernels::GemmScratchPool::new();
    kernels::lut_matmul_tiled(
        &x,
        &idx_t,
        &q.levels,
        rows,
        cin,
        cout,
        &mut single,
        kernels::Epilogue::default(),
        1,
        &mut pool,
    );
    assert_eq!(single, v1, "v2 single-thread drifted from v1");

    for threads in [2usize, 4, 7] {
        for run in 0..3 {
            let mut got = vec![0.0f32; rows * cout];
            let mut pool = kernels::GemmScratchPool::new();
            kernels::lut_matmul_tiled(
                &x,
                &idx_t,
                &q.levels,
                rows,
                cin,
                cout,
                &mut got,
                kernels::Epilogue::default(),
                threads,
                &mut pool,
            );
            assert_eq!(
                got, single,
                "threads={threads} run={run}: threaded output drifted"
            );
        }
    }
}

/// Whole-graph bit-identity between the engines: the v2 arena executor
/// (fused epilogues, tiled kernels, any thread count) reproduces the
/// PR-1 engine's logits exactly, on every architecture.
#[test]
fn graph_v2_engine_bit_identical_to_v1_engine() {
    let data = SynthDataset::generate(SynthConfig {
        n: 8,
        ..Default::default()
    });
    let batch = Batcher::eval_batches(&data, 8).remove(0);
    for (name, width) in
        [("mlp", 16usize), ("resnet8", 8), ("mobilenet_mini", 16)]
    {
        let (m, state) = synthetic::model(name, width, 10, 31).unwrap();
        let frozen =
            FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
                .unwrap();
        let graph = Graph::from_model(&frozen).unwrap();
        let weights = PreparedWeights::new(&frozen, &graph);
        let v1 = graph
            .forward(&frozen, &weights, &batch.x, batch.n, KernelMode::LutV1)
            .unwrap();
        let v2 = graph
            .forward(&frozen, &weights, &batch.x, batch.n, KernelMode::Lut)
            .unwrap();
        assert_eq!(v2, v1, "{name}: v2 engine drifted from v1");
        // multi-threaded arena run: same bits again
        let mut bufs = ExecBuffers::with_threads(4);
        let mt = graph
            .forward_into(
                &frozen,
                &weights,
                &batch.x,
                batch.n,
                KernelMode::Lut,
                &mut bufs,
            )
            .unwrap();
        assert_eq!(mt, &v1[..], "{name}: threaded arena run drifted");
    }
}

/// The acceptance-criterion test: after warmup, `forward_into` on the
/// LUT path reuses every arena buffer verbatim — no per-batch heap
/// allocation in steady-state serving. Asserted via the (ptr, capacity)
/// fingerprint of the whole arena.
#[test]
fn steady_state_lut_serving_reuses_the_arena() {
    for (name, width) in
        [("mlp", 16usize), ("resnet8", 8), ("mobilenet_mini", 16)]
    {
        let (m, state) = synthetic::model(name, width, 10, 37).unwrap();
        let frozen =
            FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
                .unwrap();
        let graph = Graph::from_model(&frozen).unwrap();
        // deployment working set: LUT-only, like a serving worker
        let weights = PreparedWeights::lut_only(&frozen, &graph);
        let img_len: usize = frozen.image.iter().product();
        let batch = 8usize;
        let x = randvec(batch * img_len, 41);
        let mut bufs = ExecBuffers::new();
        // warmup: grow every buffer to its steady-state size
        for _ in 0..2 {
            graph
                .forward_into(
                    &frozen, &weights, &x, batch, KernelMode::Lut, &mut bufs,
                )
                .unwrap();
        }
        let fp = bufs.arena_fingerprint();
        assert!(!fp.is_empty());
        for _ in 0..6 {
            graph
                .forward_into(
                    &frozen, &weights, &x, batch, KernelMode::Lut, &mut bufs,
                )
                .unwrap();
        }
        assert_eq!(
            bufs.arena_fingerprint(),
            fp,
            "{name}: arena reallocated in steady state"
        );
    }
}

/// Results must not depend on how requests were batched.
#[test]
fn batch_composition_invariance() {
    let (m, state) = synthetic::model("mobilenet_mini", 8, 10, 3).unwrap();
    let frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    let graph = Graph::from_model(&frozen).unwrap();
    let weights = PreparedWeights::new(&frozen, &graph);
    let img_len: usize = frozen.image.iter().product();
    let x = randvec(2 * img_len, 5);
    let both = graph
        .forward(&frozen, &weights, &x, 2, KernelMode::Lut)
        .unwrap();
    for i in 0..2 {
        let one = graph
            .forward(
                &frozen,
                &weights,
                &x[i * img_len..(i + 1) * img_len],
                1,
                KernelMode::Lut,
            )
            .unwrap();
        assert_eq!(one, both[i * 10..(i + 1) * 10].to_vec(), "image {i}");
    }
}

/// Manifest → export → save → load → identical model and identical
/// logits.
#[test]
fn frozen_export_disk_roundtrip() {
    let (m, state) = synthetic::model("resnet8", 8, 10, 21).unwrap();
    let frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    let dir = std::env::temp_dir().join("uniq_infer_parity_roundtrip");
    frozen.save(&dir).unwrap();
    let loaded = FrozenModel::load(&dir).unwrap();
    assert_eq!(loaded, frozen);

    let graph = Graph::from_model(&loaded).unwrap();
    let weights = PreparedWeights::new(&loaded, &graph);
    let img_len: usize = loaded.image.iter().product();
    let x = randvec(img_len, 8);
    let a = graph
        .forward(&loaded, &weights, &x, 1, KernelMode::Lut)
        .unwrap();
    let g2 = Graph::from_model(&frozen).unwrap();
    let w2 = PreparedWeights::new(&frozen, &g2);
    let b = g2.forward(&frozen, &w2, &x, 1, KernelMode::Lut).unwrap();
    assert_eq!(a, b);
}

/// Frozen weights snap to at most 2^bits distinct values per layer, and
/// the packed form really is `bits` per weight.
#[test]
fn export_respects_bit_budget() {
    let (m, state) = synthetic::model("mlp", 16, 10, 2).unwrap();
    for bits in [2u32, 3, 4, 8] {
        let f =
            FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, bits)
                .unwrap();
        for l in &f.layers {
            assert_eq!(l.k(), 1 << bits, "{} k at {bits} bits", l.name);
            assert_eq!(l.indices.bits as u32, bits, "{} width", l.name);
            assert_eq!(
                l.indices.byte_len(),
                (l.n_weights() * bits as usize).div_ceil(8),
                "{} packing density",
                l.name
            );
            let mut distinct: Vec<f32> = l.dequantize();
            distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
            distinct.dedup();
            assert!(
                distinct.len() <= 1 << bits,
                "{}: {} distinct values at {bits} bits",
                l.name,
                distinct.len()
            );
        }
    }
}

/// End-to-end through the batched server: replies match direct forward.
#[test]
fn serve_end_to_end_parity() {
    let (m, state) = synthetic::model("mobilenet_mini", 8, 10, 13).unwrap();
    let frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    let sm = Arc::new(ServeModel::new(frozen).unwrap());
    let server = Server::start(
        Arc::clone(&sm),
        ServeConfig {
            workers: 2,
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            mode: KernelMode::Lut,
            kernel_threads: 1,
            shed_after: None,
        },
    );
    let img_len = sm.image_len();
    let images: Vec<Vec<f32>> = (0..33)
        .map(|i| randvec(img_len, 100 + i as u64))
        .collect();
    let handles: Vec<_> = images
        .iter()
        .map(|img| server.submit(img.clone()).unwrap())
        .collect();
    for (img, h) in images.iter().zip(handles) {
        let reply = h.recv().expect("reply");
        let want = sm
            .graph
            .forward(&sm.model, &sm.weights, img, 1, KernelMode::Lut)
            .unwrap();
        assert_eq!(reply.logits, want);
        assert_eq!(reply.pred, kernels::argmax(&want));
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 33);
    assert!(stats.throughput_rps > 0.0);
}

/// With AOT artifacts present, the real manifest + init.bin export
/// round-trips and stays parity-clean too.
#[test]
fn artifact_manifest_export_roundtrip() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("mlp/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    for variant in ["mlp", "resnet8", "mobilenet_mini"] {
        let dir = root.join(variant);
        if !dir.join("manifest.json").exists() {
            continue;
        }
        let m = Manifest::load(&dir).unwrap();
        let state = ModelState::load_init(&m, &dir).unwrap();
        let frozen =
            FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
                .unwrap();
        assert_eq!(frozen.layers.len(), m.n_qlayers(), "{variant}");
        let graph = Graph::from_model(&frozen).unwrap();
        let weights = PreparedWeights::new(&frozen, &graph);
        let img_len: usize = frozen.image.iter().product();
        let x = randvec(img_len * 2, 31);
        let lut = graph
            .forward(&frozen, &weights, &x, 2, KernelMode::Lut)
            .unwrap();
        let refr = graph
            .forward(&frozen, &weights, &x, 2, KernelMode::DequantF32)
            .unwrap();
        let max_diff = lut
            .iter()
            .zip(&refr)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff <= 1e-5, "{variant}: diff {max_diff}");

        let tmp = std::env::temp_dir().join(format!("uniq_rt_{variant}"));
        frozen.save(&tmp).unwrap();
        assert_eq!(FrozenModel::load(&tmp).unwrap(), frozen, "{variant}");
    }
}

/// The analytic complexity view of a reconstructed graph is consistent
/// with the frozen tensors it came from.
#[test]
fn graph_to_arch_inventory_consistent() {
    let (m, state) = synthetic::model("mobilenet_mini", 16, 10, 17).unwrap();
    let frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    let graph = Graph::from_model(&frozen).unwrap();
    let arch = graph.to_arch(&frozen);
    // one analytic layer per quantizable layer
    assert_eq!(arch.layers.len(), frozen.layers.len());
    let params: u64 = arch.layers.iter().map(|l| l.params()).sum();
    assert_eq!(params, frozen.n_quantized_weights() as u64);
    // quantized complexity strictly below fp32
    let fp = arch.complexity(uniq::bops::BitConfig::baseline());
    let q4 = arch.complexity(uniq::bops::BitConfig::uniq(4, 8));
    assert!(q4.bops < fp.bops);
    assert!(q4.model_bits < fp.model_bits);
}
