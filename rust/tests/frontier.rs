//! Mixed-precision frontier suite (DESIGN.md §15).
//!
//! Gates:
//!   * the greedy search emits a **monotone** frontier on all three
//!     synthetic architectures — served BOPS strictly decreasing,
//!     degradation strictly increasing — and its start point matches
//!     the uniform allocation's served complexity;
//!   * a genuinely mixed allocation freezes into the ordinary v2
//!     format and serves **bit-identically** through the v2 AND v3
//!     engines after reload, including through the batched `Server`;
//!   * calibration provenance rides the frozen format both ways:
//!     written by the search's export, absent-but-loadable for files
//!     that predate it (the checked-in v1 fixture);
//!   * per-layer served-BOPS pricing decomposes exactly over
//!     `served_layer_bits`, and a mixed allocation is priced strictly
//!     between its all-floor and all-start uniform envelopes;
//!   * the sensitivity ranking covers every droppable (layer, dim)
//!     exactly once, every drop saves BOPS, and rows sort by
//!     degradation;
//!   * a `--data`-style calibration dir with a malformed file fails
//!     loudly with a typed error naming that file;
//!   * the family axis (`frontier_family_*`, DESIGN.md §16): every
//!     codebook family's export re-serves bit-identically through v2
//!     AND v3 (env-drivable per CI matrix cell via `UNIQ_FAMILY` /
//!     `UNIQ_FAMILY_BITS`), the best power-compand fit beats the
//!     uniform grid's occupancy balance on Gaussian weights, and
//!     `--families all` on a heterogeneous synthetic mlp yields a
//!     frontier point mixing ≥ 2 distinct families whose export
//!     round-trips and serves identically on both engines.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use uniq::coordinator::FreezeQuant;
use uniq::data::calib;
use uniq::experiments::frontier::{
    Allocation, BitDim, FrontierConfig, FrontierCtx,
};
use uniq::infer::synthetic::WeightDist;
use uniq::infer::{
    kernels, synthetic, AqMode, CalibProvenance, FrozenModel, Graph,
    KernelMode, PackedBits, PreparedWeights, ServeConfig, ServeModel,
    Server,
};
use uniq::quant::{power, QuantizerFit, Uniform};
use uniq::stats::occupancy::{bin_occupancy, occupancy_balance};
use uniq::util::rng::Rng;

const ARCHS: [(&str, usize); 3] =
    [("mlp", 16), ("resnet8", 8), ("mobilenet_mini", 8)];

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() * 0.2).collect()
}

/// Template + f32 weight basis + small calibration set for `name`.
fn basis(
    name: &str,
    width: usize,
    start_w: u32,
    calib_n: usize,
) -> (FrozenModel, Vec<Vec<f32>>, Vec<f32>) {
    let (m, state) = synthetic::model(name, width, 10, 23).unwrap();
    let template =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, start_w)
            .unwrap();
    let raw: Vec<Vec<f32>> = (0..template.layers.len())
        .map(|q| state.qlayer_weights(&m, q).unwrap().to_vec())
        .collect();
    let img_len: usize = template.image.iter().product();
    let images = randvec(calib_n * img_len, 91);
    (template, raw, images)
}

fn small_cfg() -> FrontierConfig {
    FrontierConfig {
        start_bits_w: 4,
        start_bits_a: 4,
        min_bits_w: 2,
        min_bits_a: 2,
        max_steps: 4,
        batch: 8,
        ..FrontierConfig::default()
    }
}

/// The acceptance-criterion gate: monotone frontier on every arch.
#[test]
fn frontier_monotone_all_archs() {
    for (name, width) in ARCHS {
        let (template, raw, images) = basis(name, width, 4, 8);
        let mut ctx = FrontierCtx::new(
            template, raw, images, None, small_cfg(),
        )
        .unwrap();
        let start = ctx.start_point().clone();
        assert_eq!(start.step, 0);
        assert_eq!(start.degradation, 0.0);
        assert_eq!(start.agreement, 1.0);
        let r = ctx.search().unwrap();
        assert!(
            r.trajectory.len() >= 2,
            "{name}: greedy made no progress"
        );
        assert_eq!(r.trajectory[0].alloc, start.alloc);
        assert!(!r.frontier.is_empty());
        assert!(r.selected < r.frontier.len(), "{name}");
        for w in r.frontier.windows(2) {
            assert!(
                w[1].gbops < w[0].gbops,
                "{name}: frontier BOPS not strictly decreasing: \
                 {} -> {}",
                w[0].gbops,
                w[1].gbops
            );
            assert!(
                w[1].degradation > w[0].degradation,
                "{name}: frontier degradation not increasing: \
                 {} -> {}",
                w[0].degradation,
                w[1].degradation
            );
        }
        // each greedy step drops exactly one bit somewhere
        for w in r.trajectory.windows(2) {
            let bits = |a: &Allocation| -> u32 {
                a.w.iter().map(|&b| b as u32).sum::<u32>()
                    + a.a
                        .iter()
                        .filter_map(|b| b.map(|b| b as u32))
                        .sum::<u32>()
            };
            assert_eq!(
                bits(&w[1].alloc) + 1,
                bits(&w[0].alloc),
                "{name}: a step dropped != 1 bit"
            );
            assert!(w[1].dropped.is_some());
        }
    }
}

/// A BOPS budget stops the search at the first allocation under it,
/// and the selected point honors the budget.
#[test]
fn frontier_budget_stops_and_selects_under_budget() {
    let (template, raw, images) = basis("mlp", 16, 4, 8);
    let mut probe =
        FrontierCtx::new(template, raw, images, None, small_cfg())
            .unwrap();
    let start_gbops = probe.start_point().gbops;
    let r0 = probe.search().unwrap();
    let floor_gbops = r0.frontier.last().unwrap().gbops;
    assert!(floor_gbops < start_gbops);
    // a budget halfway between floor and start is reachable
    let budget = 0.5 * (floor_gbops + start_gbops);

    let (template, raw, images) = basis("mlp", 16, 4, 8);
    let cfg = FrontierConfig {
        budget_gbops: Some(budget),
        ..small_cfg()
    };
    let mut ctx =
        FrontierCtx::new(template, raw, images, None, cfg).unwrap();
    let r = ctx.search().unwrap();
    assert_eq!(r.selected_reason, "budget");
    let sel = &r.frontier[r.selected];
    assert!(
        sel.gbops <= budget,
        "selected {} exceeds budget {budget}",
        sel.gbops
    );
    // the selected point is the FIRST (least degraded) one under budget
    for p in &r.frontier[..r.selected] {
        assert!(p.gbops > budget);
    }
}

/// End-to-end acceptance gate: a mixed allocation realizes, freezes
/// (v2, with provenance), reloads bit-exactly, and serves identical
/// logits through v2, v3 and the batched Server.
#[test]
fn mixed_allocation_freezes_and_serves_bit_identically() {
    let (template, raw, images) = basis("resnet8", 8, 4, 8);
    let mut ctx = FrontierCtx::new(
        template,
        raw,
        images,
        None,
        FrontierConfig {
            mode: AqMode::Quantile, // v3 needs aq tables; quantile is
            ..small_cfg()           // the paper-default mode
        },
    )
    .unwrap();
    ctx.provenance = Some(CalibProvenance {
        source: "/data/calib".into(),
        samples: 8,
        content_hash: "00ff00ff00ff00ff".into(),
        utc: "2026-08-08T00:00:00Z".into(),
    });

    // a deliberately heterogeneous allocation: alternating widths
    let start = ctx.start_point().alloc.clone();
    let mut alloc = start.clone();
    for q in 0..alloc.w.len() {
        if q % 2 == 0 {
            alloc.w[q] -= 1;
        }
    }
    for (q, a) in alloc.a.iter_mut().enumerate() {
        if q % 3 == 0 {
            *a = a.map(|b| b - 1);
        }
    }
    assert_ne!(alloc, start);
    let (m, weights) = ctx.realize(&alloc).unwrap();

    // per-layer truth: codebook widths really differ across layers
    let wbits: Vec<u8> =
        m.layers.iter().map(|l| l.indices.bits).collect();
    assert!(
        wbits.iter().any(|&b| b != wbits[0]),
        "allocation did not produce mixed weight widths: {wbits:?}"
    );
    let abits: Vec<usize> = m
        .aq
        .as_ref()
        .unwrap()
        .tables
        .iter()
        .filter_map(|t| t.as_ref().map(|t| t.k()))
        .collect();
    assert!(
        abits.iter().any(|&k| k != abits[0]),
        "allocation did not produce mixed table widths: {abits:?}"
    );
    assert_eq!(m.bits_w as u8, *alloc.w.iter().max().unwrap());

    // freeze → reload: bit-exact, provenance intact
    let dir = std::env::temp_dir().join("uniq_frontier_mixed_e2e");
    m.save(&dir).unwrap();
    let loaded = FrozenModel::load(&dir).unwrap();
    assert_eq!(loaded, m, "mixed model must roundtrip bit-exactly");
    assert_eq!(
        loaded.calibration.as_ref().unwrap().content_hash,
        "00ff00ff00ff00ff"
    );

    // v2 serving parity: original realize vs reloaded file
    let graph = Graph::from_model(&m).unwrap();
    let img_len: usize = m.image.iter().product();
    let x = randvec(3 * img_len, 57);
    let direct = graph
        .forward(&m, &weights, &x, 3, KernelMode::Lut)
        .unwrap();
    let g2 = Graph::from_model(&loaded).unwrap();
    let w2 = PreparedWeights::lut_only(&loaded, &g2);
    let reloaded = g2
        .forward(&loaded, &w2, &x, 3, KernelMode::Lut)
        .unwrap();
    assert_eq!(reloaded, direct, "reload changed served logits");

    // v3 (integer-only LUT²) serves the same mixed model identically
    let v3 = g2
        .forward(&loaded, &w2, &x, 3, KernelMode::LutV3)
        .unwrap();
    assert_eq!(v3, direct, "v3 drifted from v2 on mixed widths");

    // and through the batched serving tier, on both engines
    for mode in [KernelMode::Lut, KernelMode::LutV3] {
        let sm = Arc::new(ServeModel::lut_only(loaded.clone()).unwrap());
        let srv = Server::start(
            Arc::clone(&sm),
            ServeConfig {
                workers: 2,
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                mode,
                kernel_threads: 1,
                shed_after: None,
            },
        );
        let handles: Vec<_> = (0..3)
            .map(|i| {
                srv.submit(x[i * img_len..(i + 1) * img_len].to_vec())
                    .unwrap()
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let reply = h.recv().expect("reply");
            let want = &direct[i * m.classes..(i + 1) * m.classes];
            assert_eq!(
                reply.logits, want,
                "{mode:?}: served reply {i} drifted"
            );
            assert_eq!(reply.pred, kernels::argmax(want));
        }
        assert_eq!(srv.shutdown().requests, 3);
    }
}

/// The v1 fixture (no version key, no calibration section) still loads
/// with `calibration: None`; a v2 save without provenance writes a
/// loadable file; provenance roundtrips when present.
#[test]
fn provenance_optional_both_directions() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/pre_aq_frozen");
    let v1 = FrozenModel::load(&dir).unwrap();
    assert!(v1.calibration.is_none(), "v1 fixture grew provenance");

    let (m, state) = synthetic::model("mlp", 8, 10, 3).unwrap();
    let mut frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    let tmp = std::env::temp_dir().join("uniq_frontier_prov");
    frozen.save(&tmp).unwrap();
    assert!(FrozenModel::load(&tmp).unwrap().calibration.is_none());

    frozen.calibration = Some(CalibProvenance {
        source: "synthetic:977".into(),
        samples: 64,
        content_hash: "deadbeefdeadbeef".into(),
        utc: "2026-08-08T12:00:00Z".into(),
    });
    frozen.save(&tmp).unwrap();
    let back = FrozenModel::load(&tmp).unwrap();
    assert_eq!(back.calibration, frozen.calibration);
    assert_eq!(back, frozen);
}

/// Served pricing decomposes per layer: `served_complexity` equals the
/// sum over `served_layer_bits` of `bops(b_w, b_a) + params·b_w`, and
/// a mixed allocation lands strictly between its uniform envelopes.
#[test]
fn served_pricing_decomposes_over_per_layer_widths() {
    let (template, raw, images) = basis("mobilenet_mini", 8, 4, 8);
    let mut ctx =
        FrontierCtx::new(template, raw, images, None, small_cfg())
            .unwrap();
    let start = ctx.start_point().alloc.clone();
    let mut alloc = start.clone();
    alloc.w[0] -= 1;
    alloc.w[2] -= 2;
    if let Some(b) = alloc.a[1] {
        alloc.a[1] = Some(b - 1);
    }
    let (m, _w) = ctx.realize(&alloc).unwrap();
    let graph = Graph::from_model(&m).unwrap();

    let c = graph.served_complexity(&m);
    let arch = graph.to_arch(&m);
    let widths = graph.served_layer_bits(&m);
    assert_eq!(widths.len(), arch.layers.len());
    let mut bops = 0.0f64;
    let mut bits = 0.0f64;
    for (l, &(q, bw, ba)) in arch.layers.iter().zip(&widths) {
        // the reported weight width is the layer's own codebook width
        assert_eq!(
            bw,
            PackedBits::bits_for_k(m.layers[q].k()) as u32,
            "layer {q} priced at a foreign weight width"
        );
        bops += l.bops(bw, ba) + l.params() as f64 * bw as f64;
        bits += l.params() as f64 * bw as f64;
    }
    assert!(
        (c.bops / bops - 1.0).abs() < 1e-12,
        "served_complexity {} != per-layer sum {bops}",
        c.bops
    );
    assert!((c.model_bits / bits - 1.0).abs() < 1e-12);

    // strictly between the uniform envelopes
    let (mstart, _) = ctx.realize(&start).unwrap();
    let hi = graph.served_complexity(&mstart).bops;
    let floor = Allocation {
        w: vec![2; start.w.len()],
        a: start.a.iter().map(|b| b.map(|_| 2)).collect(),
        fam: start.fam.clone(),
    };
    let (mfloor, _) = ctx.realize(&floor).unwrap();
    let lo = graph.served_complexity(&mfloor).bops;
    assert!(
        lo < c.bops && c.bops < hi,
        "mixed pricing {} outside envelopes [{lo}, {hi}]",
        c.bops
    );
}

/// Sensitivity covers every droppable (layer, dim) once; every drop
/// saves BOPS; rows sort most-degrading first.
#[test]
fn sensitivity_ranking_is_complete_and_sorted() {
    let (template, raw, images) = basis("resnet8", 8, 4, 8);
    let n_layers = template.layers.len();
    let mut ctx =
        FrontierCtx::new(template, raw, images, None, small_cfg())
            .unwrap();
    let rows = ctx.sensitivity().unwrap();
    // every layer's weights can drop (4 > floor 2); every aq site's
    // activations can too — resnet8 has n_layers - 1 aq sites (final
    // dense output stays f32)
    let n_w =
        rows.iter().filter(|r| r.dim == BitDim::Weight).count();
    let n_a = rows.iter().filter(|r| r.dim == BitDim::Act).count();
    assert_eq!(n_w, n_layers);
    assert_eq!(n_a, n_layers - 1);
    let mut seen: Vec<(usize, &'static str)> = rows
        .iter()
        .map(|r| (r.q, r.dim.name()))
        .collect();
    seen.sort();
    seen.dedup();
    assert_eq!(seen.len(), rows.len(), "duplicate sensitivity rows");
    for r in &rows {
        assert!(
            r.delta_gbops > 0.0,
            "{}/{}: dropping a bit saved no BOPS",
            r.layer,
            r.dim.name()
        );
        assert!(r.delta_deg.is_finite());
    }
    for w in rows.windows(2) {
        assert!(
            w[0].delta_deg >= w[1].delta_deg,
            "sensitivity rows out of order"
        );
    }
}

/// v2-vs-v3 logit parity for one realized model against a forward
/// that was computed before save/reload.
fn assert_reserves_bit_identically(
    m: &FrozenModel,
    weights: &PreparedWeights,
    dir: &Path,
    label: &str,
) {
    let graph = Graph::from_model(m).unwrap();
    let img_len: usize = m.image.iter().product();
    let x = randvec(3 * img_len, 57);
    let direct = graph
        .forward(m, weights, &x, 3, KernelMode::Lut)
        .unwrap();
    m.save(dir).unwrap();
    let loaded = FrozenModel::load(dir).unwrap();
    assert_eq!(&loaded, m, "{label}: save/load not bit-exact");
    let g2 = Graph::from_model(&loaded).unwrap();
    let w2 = PreparedWeights::lut_only(&loaded, &g2);
    let v2 = g2
        .forward(&loaded, &w2, &x, 3, KernelMode::Lut)
        .unwrap();
    assert_eq!(v2, direct, "{label}: reload changed v2 logits");
    let v3 = g2
        .forward(&loaded, &w2, &x, 3, KernelMode::LutV3)
        .unwrap();
    assert_eq!(v3, direct, "{label}: v3 drifted from v2");
}

/// Family-matrix CI gate: each codebook family × each weight width
/// exports through the frontier's realize path and re-serves
/// bit-identically through v2 AND v3. `UNIQ_FAMILY` /
/// `UNIQ_FAMILY_BITS` pin one (family, bits) cell per CI job; unset,
/// the whole matrix runs.
#[test]
fn frontier_family_export_serves_bit_identically_v2_v3() {
    let combos: Vec<(FreezeQuant, u32)> = match (
        std::env::var("UNIQ_FAMILY"),
        std::env::var("UNIQ_FAMILY_BITS"),
    ) {
        (Ok(f), Ok(b)) => vec![(
            FreezeQuant::parse(&f)
                .unwrap_or_else(|| panic!("bad UNIQ_FAMILY '{f}'")),
            b.parse().expect("bad UNIQ_FAMILY_BITS"),
        )],
        _ => FreezeQuant::ALL
            .iter()
            .flat_map(|&f| [(f, 2u32), (f, 4u32)])
            .collect(),
    };
    for (fam, bits) in combos {
        let label = format!("{}@w{bits}", fam.name());
        let (m, state) = synthetic::model("mlp", 2, 10, 23).unwrap();
        let template =
            FrozenModel::export(&m, &state, fam, bits).unwrap();
        let raw: Vec<Vec<f32>> = (0..template.layers.len())
            .map(|q| state.qlayer_weights(&m, q).unwrap().to_vec())
            .collect();
        let img_len: usize = template.image.iter().product();
        let images = randvec(8 * img_len, 91);
        let n_layers = template.layers.len();
        let mut ctx = FrontierCtx::new(
            template,
            raw,
            images,
            None,
            FrontierConfig {
                start_bits_w: bits,
                start_bits_a: 4,
                min_bits_w: 2,
                min_bits_a: 2,
                mode: AqMode::Quantile, // v3 needs aq tables
                fq: fam,
                batch: 8,
                ..FrontierConfig::default()
            },
        )
        .unwrap();
        let start = ctx.start_point().alloc.clone();
        assert_eq!(start.fam, vec![fam; n_layers], "{label}");
        let (frozen, weights) = ctx.realize(&start).unwrap();
        assert_eq!(
            frozen.families,
            Some(vec![fam.name().to_string(); n_layers]),
            "{label}: frozen.json families section"
        );
        let dir = std::env::temp_dir()
            .join(format!("uniq_frontier_fam_{}_{bits}", fam.name()));
        assert_reserves_bit_identically(&frozen, &weights, &dir, &label);
    }
}

/// The family-matrix job's quantitative claim: on HEAVY-TAILED weights
/// (product of two normals — excess kurtosis like a trained layer's
/// outlier-laden tensor) the best power-compand fit uses alpha < 1
/// (finer bins where the mass concentrates) and spreads the weights
/// across its bins strictly better than the uniform [-3σ, 3σ] grid.
/// On a PURE Gaussian the identity alpha = 1 wins fit_best — companding
/// buys nothing there (verified in validate_family_mirror.py + the
/// power.rs unit tests) — so the fixture must actually have tails.
#[test]
fn frontier_family_power_occupancy_beats_uniform_on_heavy_tails() {
    let mut rng = Rng::new(33);
    let xs: Vec<f32> = (0..20_000)
        .map(|_| rng.normal() * rng.normal() * 0.2)
        .collect();
    for k in [4usize, 16] {
        let (alpha, qp) = power::fit_best(&xs, k);
        assert!(
            alpha < 1.0,
            "k={k}: best alpha {alpha} did not compress the tails"
        );
        let qu = Uniform.fit(&xs, k);
        let bp = occupancy_balance(&bin_occupancy(&xs, &qp.thresholds));
        let bu = occupancy_balance(&bin_occupancy(&xs, &qu.thresholds));
        assert!(
            bp > bu,
            "k={k}: power balance {bp} <= uniform balance {bu}"
        );
    }
}

/// Acceptance gate: `--families all` on a heterogeneous mlp
/// (`--synth-dist mixed`: gaussian / two-point / bounded-uniform
/// layers) emits a frontier with ≥ 1 point mixing ≥ 2 distinct
/// families, and the selected allocation's export re-serves
/// bit-identically through v2 and v3. The mix is deterministic: the
/// two-point layer reconstructs *exactly* (MSE 0) under the empirical
/// k-quantile family, which wins that tie by family order, while the
/// gaussian layer's argmin is a data-driven fit with strictly lower
/// MSE than the empirical medians.
#[test]
fn frontier_family_search_mixes_families() {
    let (m, state) =
        synthetic::model_dist("mlp", 1, 10, 23, WeightDist::Mixed)
            .unwrap();
    let template = FrozenModel::export(
        &m,
        &state,
        FreezeQuant::KQuantileGauss,
        4,
    )
    .unwrap();
    let raw: Vec<Vec<f32>> = (0..template.layers.len())
        .map(|q| state.qlayer_weights(&m, q).unwrap().to_vec())
        .collect();
    let img_len: usize = template.image.iter().product();
    let images = randvec(8 * img_len, 91);
    let mut ctx = FrontierCtx::new(
        template,
        raw,
        images,
        None,
        FrontierConfig {
            families: FreezeQuant::ALL.to_vec(),
            mode: AqMode::Quantile,
            ..small_cfg()
        },
    )
    .unwrap();

    // the start allocation already mixes: per-layer MSE argmin differs
    // across the heterogeneous layers
    let start = ctx.start_point().clone();
    assert!(
        start.alloc.distinct_families() >= 2,
        "start did not mix families: {:?}",
        start.alloc.fam
    );
    assert_eq!(
        start.alloc.fam[1],
        FreezeQuant::KQuantileEmpirical,
        "two-point fc2 must pick the exact-reconstruction family"
    );

    let r = ctx.search().unwrap();
    assert!(
        r.frontier
            .iter()
            .any(|p| p.alloc.distinct_families() >= 2),
        "no frontier point mixes families"
    );
    let sel = r.frontier[r.selected].clone();

    // per-layer occupancy evidence: one balance score per layer in (0,1]
    let occ = ctx.occupancy(&sel.alloc);
    assert_eq!(occ.len(), sel.alloc.w.len());
    assert!(
        occ.iter().all(|&o| o > 0.0 && o <= 1.0 + 1e-12),
        "occupancy balance out of range: {occ:?}"
    );

    // the selected export records its families and re-serves
    // bit-identically on both engines
    let (frozen, weights) = ctx.realize(&sel.alloc).unwrap();
    assert_eq!(
        frozen.families,
        Some(
            sel.alloc
                .fam
                .iter()
                .map(|f| f.name().to_string())
                .collect::<Vec<_>>()
        )
    );
    let dir = std::env::temp_dir().join("uniq_frontier_fam_mixed");
    assert_reserves_bit_identically(
        &frozen,
        &weights,
        &dir,
        "families-all selected",
    );
}

/// The `--data DIR` contract: a malformed calibration file fails with
/// a typed error naming that file, while a valid sibling dir loads.
#[test]
fn calib_dir_rejects_malformed_files_by_name() {
    let image = [32usize, 32, 3];
    let img_len: usize = image.iter().product();
    let root =
        std::env::temp_dir().join("uniq_frontier_calib_reject");
    let _ = std::fs::remove_dir_all(&root);

    // valid dir: two raw-f32 files, one image each
    let good = root.join("good");
    std::fs::create_dir_all(&good).unwrap();
    for (i, name) in ["a.f32", "b.f32"].iter().enumerate() {
        let bytes: Vec<u8> = randvec(img_len, i as u64)
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        std::fs::write(good.join(name), bytes).unwrap();
    }
    let set = calib::load_dir(&good, &image).unwrap();
    assert_eq!(set.n, 2);
    assert_eq!(set.files.len(), 2);
    assert_eq!(set.content_hash.len(), 16);

    // ragged file: not a whole number of images → BadLength names it
    let bad = root.join("bad");
    std::fs::create_dir_all(&bad).unwrap();
    std::fs::write(bad.join("ok.f32"), vec![0u8; img_len * 4]).unwrap();
    std::fs::write(bad.join("ragged.f32"), vec![0u8; img_len * 4 - 4])
        .unwrap();
    let err = calib::load_dir(&bad, &image).unwrap_err();
    match &err {
        calib::CalibError::BadLength { file, .. } => {
            assert!(
                file.to_string_lossy().contains("ragged.f32"),
                "error names the wrong file: {file:?}"
            );
        }
        other => panic!("expected BadLength, got {other:?}"),
    }
    assert!(
        err.to_string().contains("ragged.f32"),
        "message must name the offending file: {err}"
    );
}
