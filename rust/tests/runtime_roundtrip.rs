//! Integration: AOT artifacts -> PJRT compile -> train/eval from rust.
//!
//! Requires `make artifacts` (skipped with a notice otherwise). Uses the
//! small `mlp` and `resnet8` variants to keep compile times in CI range.

use std::path::{Path, PathBuf};

use uniq::coordinator::{
    FreezeQuant, SchedulePolicy, TrainConfig, Trainer,
};
use uniq::data::synth::{SynthConfig, SynthDataset};
use uniq::quant::QuantizerFit;
use uniq::runtime::{Engine, Manifest, ModelState};
use uniq::runtime::state::StepConfig;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("mlp/train_step.hlo.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn tiny_data(n: usize, classes: usize) -> uniq::data::Dataset {
    SynthDataset::generate(SynthConfig {
        n,
        classes,
        noise: 0.5,
        ..Default::default()
    })
}

#[test]
fn mlp_train_step_runs_and_learns() {
    let Some(root) = artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    let mut t = Trainer::new(&engine, &root.join("mlp")).unwrap();
    let data = tiny_data(256, 10);
    let n_layers = t.manifest.n_qlayers();
    let mut batcher =
        uniq::data::Batcher::new(data, t.manifest.batch, false, 3);

    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for i in 0..30 {
        let b = batcher.next_batch();
        let cfg = StepConfig {
            lr: 0.005,
            k_w: 16.0,
            k_a: 256.0,
            aq: 0.0,
            seed: i,
            mode_vec: vec![1.0; n_layers],
            qthresh: None,
        };
        let (loss, _) = t.step(&b.x, &b.y, &cfg).unwrap();
        assert!(loss.is_finite(), "loss went non-finite at step {i}");
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first * 0.9,
        "no learning: first {first}, last {last}"
    );
}

#[test]
fn frozen_mode_keeps_weights_fixed() {
    let Some(root) = artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    let mut t = Trainer::new(&engine, &root.join("mlp")).unwrap();
    let data = tiny_data(64, 10);
    let b = uniq::data::Batcher::eval_batches(&data, t.manifest.batch)
        .remove(0);
    let n_layers = t.manifest.n_qlayers();
    let before = t.state.params.clone();
    let cfg = StepConfig {
        lr: 0.5,
        k_w: 4.0,
        k_a: 16.0,
        aq: 0.0,
        seed: 1,
        mode_vec: vec![2.0; n_layers],
        qthresh: None,
    };
    t.step(&b.x, &b.y, &cfg).unwrap();
    // quantizable weights unchanged; biases/etc may move
    for (i, p) in t.manifest.params.clone().iter().enumerate() {
        if p.qlayer.is_some() {
            assert_eq!(
                t.state.params[i], before[i],
                "frozen layer {} drifted",
                p.name
            );
        }
    }
}

#[test]
fn eval_step_deterministic() {
    let Some(root) = artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    let t = Trainer::new(&engine, &root.join("mlp")).unwrap();
    let data = tiny_data(64, 10);
    let (l1, a1) = t.evaluate(&data, 256.0, 0.0).unwrap();
    let (l2, a2) = t.evaluate(&data, 256.0, 0.0).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
    assert!((0.0..=1.0).contains(&a1));
}

#[test]
fn activation_quantization_changes_eval_but_not_wildly() {
    let Some(root) = artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    let t = Trainer::new(&engine, &root.join("mlp")).unwrap();
    let data = tiny_data(64, 10);
    let (l_fp, _) = t.evaluate(&data, 256.0, 0.0).unwrap();
    let (l_q8, _) = t.evaluate(&data, 256.0, 1.0).unwrap();
    assert_ne!(l_fp, l_q8, "aq flag had no effect");
    assert!((l_fp - l_q8).abs() < 2.0, "8-bit act quant exploded");
}

#[test]
fn freeze_layer_snaps_weights_to_k_levels() {
    let Some(root) = artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    let mut t = Trainer::new(&engine, &root.join("mlp")).unwrap();
    t.freeze_layer(0, FreezeQuant::KQuantileGauss, 8).unwrap();
    let m = t.manifest.clone();
    let w = t.state.qlayer_weights(&m, 0).unwrap();
    let mut distinct: Vec<f32> = w.to_vec();
    distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
    distinct.dedup();
    assert!(
        distinct.len() <= 8,
        "{} distinct values after k=8 freeze",
        distinct.len()
    );
}

#[test]
fn gradual_run_end_to_end_resnet8() {
    let Some(root) = artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    let mut t = Trainer::new(&engine, &root.join("resnet8")).unwrap();
    let train = tiny_data(512, 10);
    let val = tiny_data(128, 10);
    let cfg = TrainConfig {
        steps_per_phase: 6,
        stages: 3,
        iterations: 1,
        policy: SchedulePolicy::Gradual,
        lr: 0.02,
        bits_w: 4,
        bits_a: 8,
        verbose: false,
        log_every: 0,
        ..Default::default()
    };
    let (loss, acc) = t.run(&train, &val, &cfg).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
    // every quantizable layer must now sit on <= 16 levels
    let m = t.manifest.clone();
    for q in 0..m.n_qlayers() {
        let w = t.state.qlayer_weights(&m, q).unwrap();
        let mut d: Vec<f32> = w.to_vec();
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        d.dedup();
        assert!(d.len() <= 16, "layer {q}: {} levels", d.len());
    }
}

#[test]
fn checkpoint_roundtrip_via_trainer_state() {
    let Some(root) = artifacts() else { return };
    let m = Manifest::load(&root.join("mlp")).unwrap();
    let s = ModelState::load_init(&m, &root.join("mlp")).unwrap();
    let path = std::env::temp_dir().join("uniq_rt_ckpt.bin");
    s.save(&path).unwrap();
    let loaded = ModelState::load(&path).unwrap();
    assert_eq!(s.params, loaded.params);
}

#[test]
fn golden_quantizer_parity_with_python() {
    // host quantizers must match the python/compile quantizers bit-near
    let Some(root) = artifacts() else { return };
    let g = root.join("golden");
    let read = |name: &str| -> Vec<f32> {
        let b = std::fs::read(g.join(format!("{name}.bin"))).unwrap();
        b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    };
    // normal cdf/icdf grids
    let zs = read("norm_z");
    let cdf = read("norm_cdf");
    for (z, c) in zs.iter().zip(&cdf) {
        let ours = uniq::stats::norm_cdf(*z as f64) as f32;
        assert!((ours - c).abs() < 2e-6, "cdf({z}): {ours} vs {c}");
    }
    let us = read("norm_u");
    let icdf = read("norm_icdf");
    for (u, v) in us.iter().zip(&icdf) {
        let ours = uniq::stats::norm_icdf(*u as f64) as f32;
        assert!((ours - v).abs() < 2e-5, "icdf({u}): {ours} vs {v}");
    }
    // gaussian k-quantile quantizer on the shared input vector
    let x = read("kq_input");
    for k in [4usize, 8, 16] {
        let want = read(&format!("kq_gauss_k{k}"));
        // python used exact mu=0.1 sigma=0.7; emulate via direct quantizer
        let q = uniq::quant::Quantizer {
            thresholds: (1..k)
                .map(|i| {
                    0.1 + 0.7 * uniq::stats::norm_icdf(i as f64 / k as f64)
                        as f32
                })
                .collect(),
            levels: (0..k)
                .map(|i| {
                    0.1 + 0.7
                        * uniq::stats::norm_icdf((i as f64 + 0.5) / k as f64)
                            as f32
                })
                .collect(),
        };
        for (xi, wi) in x.iter().zip(&want) {
            let got = q.quantize_one(*xi);
            assert!(
                (got - wi).abs() < 3e-4,
                "k={k} x={xi}: {got} vs {wi}"
            );
        }
    }
    // empirical k-quantile levels
    for k in [4usize, 8] {
        let want_levels = read(&format!("kq_emp_k{k}_levels"));
        let q = uniq::quant::KQuantileEmpirical.fit(&x, k);
        for (a, b) in q.levels.iter().zip(&want_levels) {
            assert!((a - b).abs() < 1e-5, "k={k} levels {a} vs {b}");
        }
    }
    // Lloyd-Max N(0,1) centroids
    for k in [4usize, 8] {
        let want = read(&format!("lloyd_n01_k{k}_centroids"));
        let q = uniq::quant::KMeans::fit_gaussian(k, 500);
        for (a, b) in q.levels.iter().zip(&want) {
            assert!((a - b).abs() < 5e-3, "k={k} centroid {a} vs {b}");
        }
    }
}
