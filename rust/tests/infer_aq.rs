//! Activation-quantization parity suite — the CI bitwidth matrix runs
//! this file once per (mode, bits) cell via `UNIQ_AQ_MODE` /
//! `UNIQ_AQ_BITS` (uniform, quantile and power at 4 bits when unset, so
//! a plain `cargo test` still covers every family).
//!
//! Gates, per cell:
//!   * `aq = off` stays **bit-identical** to the PR-4 engine (v1 == v2,
//!     and stripping calibrated tables restores the exact logits);
//!   * `aq = on` keeps LUT and dequant-f32 parity ≤ 1e-5 on all three
//!     architectures (the kernels share accumulation order and the
//!     identical fused epilogue, so in practice they agree bit-for-bit);
//!   * activations really snap to ≤ 2^bits levels (tracked through the
//!     arena's quantized ping-pong buffer);
//!   * the frozen format round-trips aq tables bit-exactly and still
//!     loads the checked-in pre-aq (format v1) fixture;
//!   * served BOPS use the real b_w × b_a (pinned constants).

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use uniq::bops::BitConfig;
use uniq::coordinator::FreezeQuant;
use uniq::infer::{
    actquant, kernels, synthetic, ActQuantTable, AqMode, ExecBuffers,
    FrozenModel, Graph, KernelMode, LayerCodebook, PreparedWeights,
    ServeConfig, ServeModel, Server,
};
use uniq::quant::{KQuantileGauss, QuantizerFit};
use uniq::util::rng::Rng;

const ARCHS: [(&str, usize); 3] =
    [("mlp", 16), ("resnet8", 8), ("mobilenet_mini", 16)];

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() * 0.2).collect()
}

/// The (mode, bits) cells this process covers: one cell when the CI
/// matrix sets `UNIQ_AQ_MODE`/`UNIQ_AQ_BITS`, both modes at 4 bits for
/// a plain local `cargo test`.
fn matrix_cfgs() -> Vec<(AqMode, u32)> {
    let bits = std::env::var("UNIQ_AQ_BITS")
        .ok()
        .and_then(|v| v.parse::<u32>().ok())
        .unwrap_or(4);
    match std::env::var("UNIQ_AQ_MODE") {
        Ok(m) => vec![(
            AqMode::parse(&m)
                .expect("UNIQ_AQ_MODE")
                .expect("UNIQ_AQ_MODE must not be 'none'"),
            bits,
        )],
        Err(_) => vec![
            (AqMode::Uniform, bits),
            (AqMode::Quantile, bits),
            (AqMode::Power, bits),
        ],
    }
}

/// Frozen synthetic model + its graph/weights, optionally calibrated.
fn built(
    name: &str,
    width: usize,
    aq: Option<(AqMode, u32)>,
) -> (FrozenModel, Graph, PreparedWeights) {
    let (m, state) = synthetic::model(name, width, 10, 23).unwrap();
    let mut frozen =
        FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    let graph = Graph::from_model(&frozen).unwrap();
    let weights = PreparedWeights::new(&frozen, &graph);
    if let Some((mode, bits)) = aq {
        let img_len: usize = frozen.image.iter().product();
        let calib = randvec(16 * img_len, 91);
        frozen.aq = Some(
            actquant::calibrate(
                &frozen, &graph, &weights, &calib, 8, mode, bits,
            )
            .unwrap(),
        );
    }
    (frozen, graph, weights)
}

/// aq = off is the PR-4 engine, bit for bit: v1 == v2 on every arch,
/// and a model whose calibrated tables are stripped again returns the
/// exact pre-calibration logits.
#[test]
fn aq_off_bit_identical_to_baseline_engine() {
    for (name, width) in ARCHS {
        let (frozen, graph, weights) = built(name, width, None);
        let img_len: usize = frozen.image.iter().product();
        let x = randvec(4 * img_len, 5);
        let v1 = graph
            .forward(&frozen, &weights, &x, 4, KernelMode::LutV1)
            .unwrap();
        let v2 = graph
            .forward(&frozen, &weights, &x, 4, KernelMode::Lut)
            .unwrap();
        assert_eq!(v2, v1, "{name}: aq-off v2 drifted from the v1 engine");

        for (mode, bits) in matrix_cfgs() {
            let (mut with, g2, w2) =
                built(name, width, Some((mode, bits)));
            let on = g2
                .forward(&with, &w2, &x, 4, KernelMode::Lut)
                .unwrap();
            assert!(
                on.iter().zip(&v2).any(|(a, b)| a != b),
                "{name} {mode:?}{bits}: aq changed nothing"
            );
            with.aq = None;
            let stripped = g2
                .forward(&with, &w2, &x, 4, KernelMode::Lut)
                .unwrap();
            assert_eq!(
                stripped, v2,
                "{name} {mode:?}{bits}: stripping tables must restore \
                 the exact baseline logits"
            );
        }
    }
}

/// aq = on keeps the LUT / dequant-f32 engines in lockstep on every
/// architecture (same accumulation order, same fused epilogue ⇒ the
/// mirror-validated ≤ 1e-5 contract holds with quantized activations).
#[test]
fn aq_on_lut_matches_f32_reference_all_archs() {
    for (mode, bits) in matrix_cfgs() {
        for (name, width) in ARCHS {
            let (frozen, graph, weights) =
                built(name, width, Some((mode, bits)));
            let img_len: usize = frozen.image.iter().product();
            let x = randvec(4 * img_len, 7);
            let lut = graph
                .forward(&frozen, &weights, &x, 4, KernelMode::Lut)
                .unwrap();
            let refr = graph
                .forward(&frozen, &weights, &x, 4, KernelMode::DequantF32)
                .unwrap();
            let max_diff = lut
                .iter()
                .zip(&refr)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_diff <= 1e-5,
                "{name} {mode:?}{bits}: LUT vs f32 diff {max_diff}"
            );
            assert!(
                lut.iter().all(|v| v.is_finite()),
                "{name} {mode:?}{bits}: non-finite logits"
            );
        }
    }
}

/// Single-dense graph whose output IS an aq site: the arena's quantized
/// ping-pong buffer holds the bin of every activation, values equal
/// their level, and the tensor takes at most 2^bits distinct values.
#[test]
fn aq_activations_snap_to_level_budget() {
    let (cin, cout) = (24usize, 12usize);
    let w = randvec(cin * cout, 31);
    let q = KQuantileGauss.fit(&w, 16);
    let frozen_layer =
        LayerCodebook::from_weights("fc1", &[cin, cout], &w, &q);
    for (mode, bits) in matrix_cfgs() {
        let table = ActQuantTable::from_stats(mode, bits, 0.1, 0.8);
        let mut model = FrozenModel {
            name: "aq_unit".into(),
            image: vec![1, 1, cin],
            classes: cout,
            bits_w: 4,
            layers: vec![frozen_layer.clone()],
            params: vec![],
            state: vec![],
            aq: Some(uniq::infer::ActQuantModel {
                mode,
                bits: bits as u8,
                tables: vec![Some(table.clone())],
            }),
            calibration: None,
            families: None,
        };
        // ops mirror build_mlp's non-final dense: relu'd => aq site
        let graph = Graph::new(
            vec![
                uniq::infer::graph::Op::Flatten,
                uniq::infer::graph::Op::Dense { q: 0, bias: None },
                uniq::infer::graph::Op::Relu,
            ],
            "mlp",
        );
        let weights = PreparedWeights::new(&model, &graph);
        let batch = 5usize;
        let x = randvec(batch * cin, 33);
        let mut bufs = ExecBuffers::new();
        bufs.track_qact = true;
        let logits = graph
            .forward_into(
                &model, &weights, &x, batch, KernelMode::Lut, &mut bufs,
            )
            .unwrap()
            .to_vec();
        let qact = bufs.qact().to_vec();
        assert_eq!(qact.len(), logits.len(), "one bin per activation");
        let mut distinct: Vec<f32> = logits.clone();
        for (i, (&v, &b)) in logits.iter().zip(&qact).enumerate() {
            assert!(
                (b as usize) < table.levels.len(),
                "bin {b} out of range"
            );
            assert_eq!(
                v, table.levels[b as usize],
                "activation {i} is not its level"
            );
        }
        distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
        distinct.dedup();
        assert!(
            distinct.len() <= 1 << bits,
            "{} distinct values at {bits} bits",
            distinct.len()
        );

        // untracked run produces the same values with an empty buffer
        let mut plain = ExecBuffers::new();
        let l2 = graph
            .forward_into(
                &model, &weights, &x, batch, KernelMode::Lut, &mut plain,
            )
            .unwrap()
            .to_vec();
        assert_eq!(l2, logits, "tracking changed the numbers");
        assert!(plain.qact().is_empty());

        // aq-off on the same graph: values leave the level grid
        model.aq = None;
        let off = graph
            .forward(&model, &weights, &x, batch, KernelMode::Lut)
            .unwrap();
        assert!(off.iter().zip(&logits).any(|(a, b)| a != b));
    }
}

/// `--aq quantile --aq-bits 4` serves every arch through the batched
/// tier with replies bit-identical to the direct forward (the
/// acceptance-criterion configuration).
#[test]
fn aq_quantile4_serves_all_archs() {
    for (name, width) in ARCHS {
        let (m, state) = synthetic::model(name, width, 10, 41).unwrap();
        let frozen =
            FrozenModel::export(&m, &state, FreezeQuant::KQuantileGauss, 4)
                .unwrap();
        let mut sm = ServeModel::new(frozen).unwrap();
        let img_len = sm.image_len();
        let calib = randvec(12 * img_len, 43);
        sm.calibrate_aq(AqMode::Quantile, 4, &calib, 6).unwrap();
        assert_eq!(sm.model.bits_a(), 4);
        let sm = Arc::new(sm);
        let srv = Server::start(
            Arc::clone(&sm),
            ServeConfig {
                workers: 2,
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                mode: KernelMode::Lut,
                kernel_threads: 1,
                shed_after: None,
            },
        );
        let images: Vec<Vec<f32>> = (0..9)
            .map(|i| randvec(img_len, 50 + i as u64))
            .collect();
        let handles: Vec<_> = images
            .iter()
            .map(|img| srv.submit(img.clone()).unwrap())
            .collect();
        for (img, h) in images.iter().zip(handles) {
            let reply = h.recv().expect("reply");
            let want = sm
                .graph
                .forward(&sm.model, &sm.weights, img, 1, KernelMode::Lut)
                .unwrap();
            assert_eq!(
                reply.logits, want,
                "{name}: served aq logits drifted"
            );
            assert_eq!(reply.pred, kernels::argmax(&want));
        }
        assert_eq!(srv.shutdown().requests, 9, "{name}");
    }
}

/// Frozen-format round trip with aq tables: save → load is bit-exact
/// (model equality AND logit equality), for every matrix cell.
#[test]
fn frozen_roundtrip_with_aq_is_bit_exact() {
    for (mode, bits) in matrix_cfgs() {
        let (frozen, graph, weights) =
            built("resnet8", 8, Some((mode, bits)));
        let dir = std::env::temp_dir().join(format!(
            "uniq_aq_roundtrip_{}_{bits}",
            mode.name()
        ));
        frozen.save(&dir).unwrap();
        let loaded = FrozenModel::load(&dir).unwrap();
        assert_eq!(loaded, frozen, "{mode:?}{bits}: model roundtrip");

        let img_len: usize = frozen.image.iter().product();
        let x = randvec(2 * img_len, 61);
        let g2 = Graph::from_model(&loaded).unwrap();
        let w2 = PreparedWeights::new(&loaded, &g2);
        let a = graph
            .forward(&frozen, &weights, &x, 2, KernelMode::Lut)
            .unwrap();
        let b = g2.forward(&loaded, &w2, &x, 2, KernelMode::Lut).unwrap();
        assert_eq!(a, b, "{mode:?}{bits}: logits after reload");
    }
}

/// The checked-in pre-aq fixture (format v1: no version key, no
/// act_quant section) still loads and serves — with pinned logits, all
/// of whose inputs/weights are exact binary fractions.
#[test]
fn pre_aq_fixture_loads_and_serves() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/pre_aq_frozen");
    let m = FrozenModel::load(&dir).unwrap();
    assert_eq!(m.name, "pre_aq_mlp");
    assert!(m.aq.is_none(), "v1 fixture must load with aq = None");
    assert_eq!(m.bits_a(), 32);
    assert_eq!(m.bits_w, 2);
    assert_eq!(m.layers.len(), 2);

    let graph = Graph::from_model(&m).unwrap();
    let weights = PreparedWeights::new(&m, &graph);
    // exact /8 fractions: every intermediate is exactly representable,
    // so the logits pin bit-for-bit (make_pre_aq_fixture.py prints them)
    let x: Vec<f32> =
        (0..12).map(|i| ((i * 7) % 13) as f32 / 8.0 - 0.5).collect();
    let got = graph.forward(&m, &weights, &x, 1, KernelMode::Lut).unwrap();
    assert_eq!(got, vec![4.8125, 21.5, -21.25, -4.5625]);
    assert_eq!(kernels::argmax(&got), 1);

    // and it serves end to end
    let sm = Arc::new(ServeModel::new(m).unwrap());
    let srv = Server::start(Arc::clone(&sm), ServeConfig::default());
    let reply = srv.submit(x).unwrap().recv().unwrap();
    assert_eq!(reply.pred, 1);
    assert_eq!(srv.shutdown().requests, 1);
}

/// Served BOPS use the real b_w × b_a: pinned totals for the synthetic
/// archs at (w4,a4) and (w2,a8) — constants cross-computed by an
/// independent python replica of the formula (see PR notes), tolerance
/// 1e-6 relative for libm log2 drift.
#[test]
fn served_bops_pinned_at_real_bitwidths() {
    let cases: [(&str, usize, f64, f64); 2] = [
        // (arch, width, bops at (4,4), bops at (2,8))
        ("resnet8", 8, 99_289_186.257_532_06, 105_722_290.257_532_06),
        (
            "mobilenet_mini",
            16,
            92_630_623.284_715_32,
            98_936_671.284_715_32,
        ),
    ];
    for (name, width, want44, want28) in cases {
        let (frozen, graph, _weights) = built(name, width, None);
        let arch = graph.to_arch(&frozen);
        let got44 = arch.complexity(BitConfig::uniq(4, 4)).bops;
        let got28 = arch.complexity(BitConfig::uniq(2, 8)).bops;
        assert!(
            (got44 / want44 - 1.0).abs() < 1e-6,
            "{name} (4,4): got {got44}, want {want44}"
        );
        assert!(
            (got28 / want28 - 1.0).abs() < 1e-6,
            "{name} (2,8): got {got28}, want {want28}"
        );
    }

    // served_complexity prices per-layer INPUT widths: without tables
    // it reduces exactly to the all-32 activation pricing
    let (frozen, graph, _w) = built("resnet8", 8, None);
    let fp_a = graph.served_complexity(&frozen).bops;
    let want_fp =
        graph.to_arch(&frozen).complexity(BitConfig::uniq(4, 32)).bops;
    assert_eq!(fp_a, want_fp);

    // with quantile-4 tables: every layer fed by a quantized output
    // prices at b_a=4, but conv1 (reads the f32 image) and fc (reads
    // global-avg-pooled values, off the level grid) stay at 32
    let (aq4, g2, _w2) = built("resnet8", 8, Some((AqMode::Quantile, 4)));
    let q_a = g2.served_complexity(&aq4).bops;
    let arch = g2.to_arch(&aq4);
    let all4 = arch.complexity(BitConfig::uniq(4, 4)).bops;
    assert!(
        all4 < q_a && q_a < fp_a,
        "first/last f32 inputs: expected {all4} < {q_a} < {fp_a}"
    );
    let first = &arch.layers[0];
    let last = arch.layers.last().unwrap();
    let want = all4 + (first.bops(4, 32) - first.bops(4, 4))
        + (last.bops(4, 32) - last.bops(4, 4));
    assert!(
        (q_a / want - 1.0).abs() < 1e-9,
        "served pricing drifted: got {q_a}, want {want}"
    );
}

/// Calibration is a pure function of (model, images, mode, bits).
#[test]
fn calibration_is_deterministic() {
    for (mode, bits) in matrix_cfgs() {
        let (frozen, graph, weights) = built("mobilenet_mini", 8, None);
        let img_len: usize = frozen.image.iter().product();
        let calib = randvec(8 * img_len, 71);
        let a = actquant::calibrate(
            &frozen, &graph, &weights, &calib, 4, mode, bits,
        )
        .unwrap();
        let b = actquant::calibrate(
            &frozen, &graph, &weights, &calib, 4, mode, bits,
        )
        .unwrap();
        assert_eq!(a, b, "{mode:?}{bits}: calibration not deterministic");
        // every aq site got a table; the final dense did not
        let fc = frozen.layer_index("fc").unwrap();
        assert!(a.tables[fc].is_none(), "final dense must stay f32");
        assert_eq!(
            a.n_tables(),
            frozen.layers.len() - 1,
            "all non-final qlayers have aq sites"
        );
        // batch size must not change the tables (pure fold)
        let c = actquant::calibrate(
            &frozen, &graph, &weights, &calib, 3, mode, bits,
        )
        .unwrap();
        assert_eq!(a, c, "{mode:?}{bits}: batch-size dependence");
    }
}

/// Steady-state serving with aq on (and bin tracking) still reuses the
/// arena verbatim — the zero-allocation contract extends to the
/// quantized ping-pong pair.
#[test]
fn aq_serving_keeps_the_arena_allocation_free() {
    for (mode, bits) in matrix_cfgs() {
        let (frozen, graph, _full) =
            built("mobilenet_mini", 8, Some((mode, bits)));
        let weights = PreparedWeights::lut_only(&frozen, &graph);
        let img_len: usize = frozen.image.iter().product();
        let batch = 4usize;
        let x = randvec(batch * img_len, 81);
        let mut bufs = ExecBuffers::new();
        bufs.track_qact = true;
        for _ in 0..2 {
            graph
                .forward_into(
                    &frozen, &weights, &x, batch, KernelMode::Lut,
                    &mut bufs,
                )
                .unwrap();
        }
        let fp = bufs.arena_fingerprint();
        for _ in 0..4 {
            graph
                .forward_into(
                    &frozen, &weights, &x, batch, KernelMode::Lut,
                    &mut bufs,
                )
                .unwrap();
        }
        assert_eq!(
            bufs.arena_fingerprint(),
            fp,
            "{mode:?}{bits}: arena reallocated in steady state"
        );
        assert!(!bufs.qact().is_empty(), "tracking recorded nothing");
    }
}
