//! Native-backend correctness: finite-difference gradients, the full
//! gradual schedule end-to-end (train → freeze → LUT serve parity), and
//! backend-independence of the freeze path. The jax ground truth for the
//! same math lives in `python/tools/validate_train_mirror.py` (the
//! train-side sibling of `validate_infer_mirror.py`).

use uniq::coordinator::{FreezeQuant, SchedulePolicy, TrainConfig, Trainer};
use uniq::data::synth::{SynthConfig, SynthDataset};
use uniq::infer::{synthetic, FrozenModel, Graph, KernelMode, PreparedWeights};
use uniq::runtime::manifest::ParamMeta;
use uniq::runtime::state::StepConfig;
use uniq::runtime::{Backend, Manifest, ModelState};
use uniq::train::NativeBackend;
use uniq::util::rng::Rng;

/// Hand-built tiny MLP manifest (image 2x2x3 -> 8 -> 4 classes): small
/// enough to finite-difference every coordinate.
fn tiny_mlp(seed: u64) -> (Manifest, ModelState) {
    let dims = [(12usize, 8usize), (8, 4)];
    let mut params = Vec::new();
    let mut pvals = Vec::new();
    let mut rng = Rng::new(seed);
    let mut offset = 0usize;
    for (i, &(cin, cout)) in dims.iter().enumerate() {
        let name = format!("fc{}", i + 1);
        let scale = (2.0 / cin as f32).sqrt();
        let w: Vec<f32> =
            (0..cin * cout).map(|_| rng.normal() * scale).collect();
        params.push(ParamMeta {
            name: format!("{name}/w"),
            shape: vec![cin, cout],
            qlayer: Some(i),
            wd: true,
            offset,
            size: cin * cout,
        });
        offset += cin * cout;
        pvals.push(w);
        params.push(ParamMeta {
            name: format!("{name}/b"),
            shape: vec![cout],
            qlayer: None,
            wd: false,
            offset,
            size: cout,
        });
        offset += cout;
        pvals.push(vec![0.0; cout]);
    }
    let momenta = pvals.iter().map(|p| vec![0.0; p.len()]).collect();
    let manifest = Manifest {
        name: "tiny_mlp".into(),
        batch: 4,
        image: vec![2, 2, 3],
        classes: 4,
        noise_cfg: "quantile".into(),
        kmax: 32,
        qlayers: vec!["fc1".into(), "fc2".into()],
        params,
        state: vec![],
        train_inputs: vec![],
        train_outputs: vec![],
        eval_inputs: vec![],
        eval_outputs: vec![],
    };
    let state = ModelState { params: pvals, momenta, state: vec![], step: 0 };
    (manifest, state)
}

fn rand_batch(
    d_in: usize,
    n: usize,
    classes: usize,
    seed: u64,
) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let x = (0..n * d_in).map(|_| rng.normal()).collect();
    let y = (0..n).map(|_| rng.below(classes) as i32).collect();
    (x, y)
}

/// Full-precision gradients vs central finite differences, every
/// coordinate. Gradients are recovered from the update equation with
/// zero initial momentum: g = (p - p') / lr - wd * p.
#[test]
fn fp_gradients_match_finite_differences() {
    let (m, state) = tiny_mlp(3);
    let backend = NativeBackend::new(&m).unwrap().with_threads(1);
    // reject batches with a first-layer pre-activation near the relu
    // kink: a central difference straddling z = 0 disagrees with the
    // (one-sided) analytic gradient there by construction
    let mut seed = 4u64;
    let (x, y) = loop {
        let (x, y) = rand_batch(12, 6, 4, seed);
        let (w1, b1) = (&state.params[0], &state.params[1]);
        let mut min_abs = f32::INFINITY;
        for r in 0..6 {
            for j in 0..8 {
                let mut z = b1[j];
                for c in 0..12 {
                    z += x[r * 12 + c] * w1[c * 8 + j];
                }
                min_abs = min_abs.min(z.abs());
            }
        }
        if min_abs > 0.08 {
            break (x, y);
        }
        seed += 1;
        assert!(seed < 200, "no kink-free batch found");
    };
    let lr = 0.5f32; // large lr so (p - p') resolves g in f32
    let cfg = StepConfig {
        lr,
        k_w: 16.0,
        k_a: 256.0,
        aq: 0.0,
        seed: 1,
        mode_vec: vec![0.0, 0.0],
        qthresh: None,
    };
    let mut stepped = state.clone();
    backend.train_step(&m, &mut stepped, &x, &y, &cfg).unwrap();

    let loss_at = |st: &ModelState| -> f32 {
        backend.eval_step(&m, st, &x, &y, 256.0, 0.0).unwrap().0
    };
    let h = 1e-2f32;
    for pi in 0..state.params.len() {
        let wd = if m.params[pi].wd {
            uniq::train::ops::WEIGHT_DECAY
        } else {
            0.0
        };
        for ci in 0..state.params[pi].len() {
            let g = (state.params[pi][ci] - stepped.params[pi][ci]) / lr
                - wd * state.params[pi][ci];
            let mut probe = state.clone();
            probe.params[pi][ci] += h;
            let lp = loss_at(&probe);
            probe.params[pi][ci] -= 2.0 * h;
            let lm = loss_at(&probe);
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (fd - g).abs() < 0.01 * g.abs().max(0.5) + 2e-3,
                "{} [{ci}]: finite-diff {fd} vs analytic {g}",
                m.params[pi].name
            );
        }
    }
}

/// The whole paper procedure on the native backend: gradual schedule,
/// per-phase freeze, frozen checkpoint → LUT export → serve parity.
#[test]
fn gradual_schedule_trains_freezes_and_serves() {
    let mut t = Trainer::native_synthetic("mlp", 2, 10, 11).unwrap();
    assert_eq!(t.backend.name(), "native");
    let train = SynthDataset::generate(SynthConfig {
        n: 256,
        noise: 0.6,
        ..Default::default()
    });
    let val = SynthDataset::generate(SynthConfig {
        n: 64,
        noise: 0.6,
        sample_seed: 4321,
        ..Default::default()
    });
    let (l0, _) = t.evaluate(&val, 256.0, 0.0).unwrap();
    let cfg = TrainConfig {
        steps_per_phase: 25,
        stages: 0, // one stage per layer
        iterations: 2,
        policy: SchedulePolicy::Gradual,
        lr: 0.05,
        bits_w: 4,
        bits_a: 8,
        eval_act_quant: false,
        freeze_quant: FreezeQuant::KQuantileGauss,
        seed: 7,
        log_every: 0,
        eval_every: 0,
        verbose: false,
    };
    let (l1, a1) = t.run(&train, &val, &cfg).unwrap();
    assert!(l1.is_finite() && (0.0..=1.0).contains(&a1));
    assert!(l1 < l0, "training must reduce val loss: {l0} -> {l1}");
    assert_eq!(t.state.step, (3 * 2 * 25) as u64);

    // every quantizable layer froze onto <= 2^4 distinct levels
    for qidx in 0..t.manifest.n_qlayers() {
        let w = t.state.qlayer_weights(&t.manifest, qidx).unwrap();
        let mut distinct: Vec<f32> = w.to_vec();
        distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
        distinct.dedup();
        assert!(
            distinct.len() <= 16,
            "qlayer {qidx}: {} distinct values after freeze",
            distinct.len()
        );
    }

    // frozen checkpoint flows straight into the LUT serving engine
    let frozen = FrozenModel::export(
        &t.manifest,
        &t.state,
        FreezeQuant::KQuantileGauss,
        4,
    )
    .unwrap();
    let graph = Graph::from_model(&frozen).unwrap();
    let weights = PreparedWeights::new(&frozen, &graph);
    let b = &val;
    let x = &b.images[..4 * b.image_len()];
    let lut = graph
        .forward(&frozen, &weights, x, 4, KernelMode::Lut)
        .unwrap();
    let refr = graph
        .forward(&frozen, &weights, x, 4, KernelMode::DequantF32)
        .unwrap();
    let maxd = lut
        .iter()
        .zip(&refr)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(maxd <= 1e-5, "LUT vs dequant-f32 diff {maxd}");
    // the frozen weights ARE the codebook expansion (freeze idempotent)
    for (qidx, layer) in frozen.layers.iter().enumerate() {
        let w = t.state.qlayer_weights(&t.manifest, qidx).unwrap();
        assert_eq!(layer.dequantize(), w, "layer {} drifted", layer.name);
    }
}

/// Satellite: the native backend's freeze path must produce codebooks
/// bit-identical to the PJRT path's host-side freeze — both run the same
/// `Trainer::freeze_layer` over `ModelState`, so the exported
/// `FrozenModel`s must be equal byte for byte.
#[test]
fn freeze_path_bit_identical_across_backends() {
    let (m, state) = synthetic::mlp(32, 10, 5);
    // native-trainer freeze (what `uniq train` does at phase end)
    let backend = NativeBackend::new(&m).unwrap().with_threads(2);
    let mut t = Trainer::with_backend(m.clone(), state.clone(), Box::new(backend));
    let (x, y) = rand_batch(3072, 8, 10, 6);
    let cfg = StepConfig {
        lr: 0.01,
        k_w: 16.0,
        k_a: 256.0,
        aq: 0.0,
        seed: 2,
        mode_vec: vec![1.0; 3],
        qthresh: None,
    };
    t.step(&x, &y, &cfg).unwrap();
    let mut pjrt_style = t.state.clone(); // same weights, frozen manually
    for qidx in 0..m.n_qlayers() {
        t.freeze_layer(qidx, FreezeQuant::KQuantileGauss, 16).unwrap();
    }
    let native_frozen =
        FrozenModel::export(&m, &t.state, FreezeQuant::KQuantileGauss, 4)
            .unwrap();

    // the PJRT path's host-side freeze: identical quantizer over the
    // same ModelState, no trainer involved
    for qidx in 0..m.n_qlayers() {
        let w = pjrt_style.qlayer_weights_mut(&m, qidx).unwrap();
        let q = FreezeQuant::KQuantileGauss.fit(w, 16);
        q.quantize(w);
    }
    let pjrt_frozen =
        FrozenModel::export(&m, &pjrt_style, FreezeQuant::KQuantileGauss, 4)
            .unwrap();

    assert_eq!(
        native_frozen, pjrt_frozen,
        "freeze must be backend-independent"
    );
    for (a, b) in native_frozen.layers.iter().zip(&pjrt_frozen.layers) {
        assert_eq!(a.indices.data, b.indices.data, "{}: packed bits", a.name);
        assert_eq!(a.codebook, b.codebook, "{}: codebook", a.name);
    }
}

/// Noise-mode steps must leave no NaN/inf anywhere and keep improving
/// (smoke for longer simultaneous-noise runs).
#[test]
fn simultaneous_noise_training_stays_finite() {
    let (m, state) = synthetic::mlp(16, 10, 9);
    let backend = NativeBackend::new(&m).unwrap();
    let mut st = state;
    let (x, y) = rand_batch(3072, 8, 10, 10);
    let cfg = StepConfig {
        lr: 0.02,
        k_w: 4.0, // 2-bit weights: widest noise
        k_a: 16.0,
        aq: 1.0, // activation quant on as well
        seed: 3,
        mode_vec: vec![1.0; 3],
        qthresh: None,
    };
    let mut last = f32::INFINITY;
    for step in 0..10i32 {
        let mut c = cfg.clone();
        c.seed = step;
        let (loss, _) = backend.train_step(&m, &mut st, &x, &y, &c).unwrap();
        assert!(loss.is_finite(), "step {step}: loss {loss}");
        last = loss;
    }
    assert!(last.is_finite());
    for group in [&st.params, &st.momenta] {
        for t in group {
            assert!(t.iter().all(|v| v.is_finite()), "non-finite state");
        }
    }
}
