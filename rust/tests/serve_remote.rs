//! Multi-host serving integration tests: the `infer::net` wire
//! transport, remote workers, cross-process supervision, and router
//! backpressure under remote-shaped latency.
//!
//! The fast tests run in the tier-1 gate (`cargo test -q`); two of
//! them spawn real `uniq serve --remote-worker` child processes via
//! `CARGO_BIN_EXE_uniq` and round-trip traffic over loopback. The
//! chaos soak — hundreds of requests across 2 spawned worker
//! processes with one SIGKILLed at the halfway submit, asserting zero
//! dropped requests and bit-identical outputs vs a direct forward —
//! is `#[ignore]`d and driven explicitly by the CI bench job:
//!
//!     cargo test --release -q --test serve_remote -- soak --ignored
//!
//! The deterministic chaos matrix — scripted `--fault-plan` faults
//! (stall/corrupt/delay/freeze) on one of two spawned workers, crossed
//! with routing policies — is likewise `#[ignore]`d and driven by the
//! CI chaos-matrix job:
//!
//!     UNIQ_CHAOS_FAULT=stall UNIQ_CHAOS_ROUTING=rr \
//!         cargo test --release -q --test serve_remote -- chaos --ignored

use std::collections::VecDeque;
use std::io::{BufRead, BufReader};
use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use uniq::coordinator::FreezeQuant;
use uniq::infer::net::frame::{write_frame, FrameKind};
use uniq::infer::net::{
    submit_blocking, FaultPlan, Hello, ModelExpect, RemoteOpts,
    RemoteReplica, Supervisor, Worker, WorkerSpec, PROTO_VERSION,
};
use uniq::infer::{
    synthetic, FrozenModel, KernelMode, Pending, RawServeStats, Reply,
    ReplicaBackend, ReplicaFactory, Router, RouterConfig, RoutingPolicy,
    ServeConfig, ServeModel, SubmitError,
};
use uniq::util::rng::Rng;

fn model() -> Arc<ServeModel> {
    let (m, st) = synthetic::mlp(32, 10, 7);
    let frozen =
        FrozenModel::export(&m, &st, FreezeQuant::KQuantileGauss, 4)
            .unwrap();
    Arc::new(ServeModel::new(frozen).unwrap())
}

fn serve_cfg(max_wait: Duration) -> ServeConfig {
    ServeConfig {
        workers: 1,
        max_batch: 16,
        max_wait,
        mode: KernelMode::Lut,
        kernel_threads: 1,
        shed_after: None,
    }
}

fn images(sm: &ServeModel, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let img_len = sm.image_len();
    (0..n)
        .map(|_| (0..img_len).map(|_| rng.normal()).collect())
        .collect()
}

fn expect_of(sm: &ServeModel) -> (usize, usize) {
    (sm.image_len(), sm.model.classes)
}

/// A factory that dials a fixed worker address — the remote analogue
/// of the local `Server::start_with` closure the router builds itself.
fn connect_factory(
    addr: String,
    expect: (usize, usize),
) -> ReplicaFactory {
    Box::new(move |outstanding| {
        let r = RemoteReplica::connect(
            &addr,
            Some(expect),
            RemoteOpts::default(),
            outstanding,
        )?;
        Ok(Box::new(r) as Box<dyn ReplicaBackend>)
    })
}

/// One `RemoteReplica` against one in-process worker: every reply is
/// bit-identical to a direct single-image forward, client and worker
/// accounting agree, and the drain barrier hands the worker-side batch
/// histogram back over the wire.
#[test]
fn remote_worker_roundtrip_bit_identical() {
    let sm = model();
    let worker = Worker::bind(
        Arc::clone(&sm),
        serve_cfg(Duration::from_millis(1)),
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = worker.addr().to_string();
    let handle = worker.spawn();

    let replica = RemoteReplica::connect(
        &addr,
        Some(expect_of(&sm)),
        RemoteOpts::default(),
        Arc::new(AtomicUsize::new(0)),
    )
    .unwrap();
    assert_eq!(replica.hello().img_len as usize, sm.image_len());
    assert_eq!(replica.hello().classes as usize, sm.model.classes);
    assert!(replica.hello().model.contains("mlp"));
    assert!(replica.alive());

    let imgs = images(&sm, 24, 3);
    let pending: Vec<_> = imgs
        .iter()
        .map(|img| {
            submit_blocking(
                &replica,
                img.clone(),
                Duration::from_secs(5),
            )
            .expect("submit")
        })
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let reply = rx.recv().unwrap();
        let want = sm
            .graph
            .forward(&sm.model, &sm.weights, &imgs[i], 1, KernelMode::Lut)
            .unwrap();
        assert_eq!(
            reply.logits, want,
            "request {i}: logits drifted across the wire"
        );
        assert_eq!(reply.pred, uniq::infer::kernels::argmax(&want));
    }
    assert_eq!(replica.outstanding(), 0, "all replies accounted");

    let stats = replica.drain_then_stop();
    assert_eq!(stats.images, 24, "client-side reply count");
    assert_eq!(
        stats.batch_sizes.iter().sum::<usize>(),
        24,
        "DrainAck must carry the worker-side batch histogram"
    );
    handle.shutdown();
}

/// The Hello handshake pins fleet geometry: a worker serving a
/// different snapshot shape fails at connect, loudly, instead of
/// silently returning different logits. Wrong-length submits are
/// refused locally, and a killed replica hands images back.
#[test]
fn handshake_and_submit_reject_bad_geometry() {
    let sm = model();
    let worker = Worker::bind(
        Arc::clone(&sm),
        serve_cfg(Duration::from_millis(1)),
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = worker.addr().to_string();
    let handle = worker.spawn();

    let err = RemoteReplica::connect(
        &addr,
        Some((sm.image_len() + 1, sm.model.classes)),
        RemoteOpts::default(),
        Arc::new(AtomicUsize::new(0)),
    );
    assert!(err.is_err(), "geometry mismatch must fail the handshake");

    let replica = RemoteReplica::connect(
        &addr,
        Some(expect_of(&sm)),
        RemoteOpts::default(),
        Arc::new(AtomicUsize::new(0)),
    )
    .unwrap();
    let short = vec![0.0f32; 5];
    match replica.try_submit(short.clone()) {
        Err(img) => assert_eq!(img, short, "refused image handed back"),
        Ok(_) => panic!("wrong-length image must be refused"),
    }

    replica.kill();
    assert!(!replica.alive());
    let img = vec![0.0f32; sm.image_len()];
    assert!(
        replica.try_submit(img).is_err(),
        "a killed replica must refuse new submits"
    );
    handle.shutdown();
}

/// Two remote workers behind the router; worker 1's connections are
/// severed with its queue full (the in-process stand-in for SIGKILL).
/// Every queued request resubmits through the surviving worker — zero
/// drops, bit-identical replies, loss and resubmission accounted.
#[test]
fn fleet_kill_one_worker_resubmits_zero_drops() {
    let sm = model();
    // long collector wait so the first wave is still queued at the kill
    let w0 = Worker::bind(
        Arc::clone(&sm),
        serve_cfg(Duration::from_millis(150)),
        "127.0.0.1:0",
    )
    .unwrap();
    let w1 = Worker::bind(
        Arc::clone(&sm),
        serve_cfg(Duration::from_millis(150)),
        "127.0.0.1:0",
    )
    .unwrap();
    let (a0, a1) = (w0.addr().to_string(), w1.addr().to_string());
    let (h0, h1) = (w0.spawn(), w1.spawn());

    let expect = expect_of(&sm);
    let router = Router::start_with_backends(
        RouterConfig {
            replicas: 2,
            policy: RoutingPolicy::RoundRobin,
            queue_cap: 1024,
            // no monitor: the test exercises the submit/recv paths'
            // own down-marking and resubmission, not reconnection
            health_every: Duration::ZERO,
            max_retries: 8,
            seed: 11,
            request_timeout: None,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            serve: serve_cfg(Duration::from_millis(150)),
        },
        sm.image_len(),
        vec![
            connect_factory(a0, expect),
            connect_factory(a1, expect),
        ],
    );

    let imgs = images(&sm, 16, 21);
    let pending: Vec<_> = imgs
        .iter()
        .map(|img| router.submit(img).expect("submit"))
        .collect();
    // round-robin queued 8 on each worker; worker 1 dies abruptly
    h1.kill();
    for (i, p) in pending.into_iter().enumerate() {
        let reply = p.recv().unwrap_or_else(|e| {
            panic!("request {i} dropped across the worker kill: {e}")
        });
        let want = sm
            .graph
            .forward(&sm.model, &sm.weights, &imgs[i], 1, KernelMode::Lut)
            .unwrap();
        assert_eq!(reply.logits, want, "request {i}: logits drifted");
    }
    let fleet = router.shutdown();
    assert_eq!(
        fleet.fleet.requests, 16,
        "every request served exactly once across the kill"
    );
    assert_eq!(
        fleet.lost_in_flight, 8,
        "worker 1's queued wave was lost with the kill"
    );
    assert_eq!(fleet.resubmits, 8, "and resubmitted by its Pendings");
    h1.shutdown();
    h0.shutdown();
}

/// A factory whose worker address refuses connections: the router
/// starts anyway (slot empty, marked down), traffic flows through the
/// live worker, and later heal sweeps keep failing without wedging
/// anything — the connecting→dead edge of the supervision machine.
#[test]
fn unreachable_worker_slot_degrades_gracefully() {
    let sm = model();
    let worker = Worker::bind(
        Arc::clone(&sm),
        serve_cfg(Duration::from_millis(1)),
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = worker.addr().to_string();
    let handle = worker.spawn();
    // bind-then-drop: a loopback port with (almost surely) no listener
    let dead_addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };

    let expect = expect_of(&sm);
    let router = Router::start_with_backends(
        RouterConfig {
            replicas: 2,
            policy: RoutingPolicy::LeastOutstanding,
            queue_cap: 1024,
            health_every: Duration::ZERO,
            max_retries: 8,
            seed: 11,
            request_timeout: None,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            serve: serve_cfg(Duration::from_millis(1)),
        },
        sm.image_len(),
        vec![
            connect_factory(addr, expect),
            connect_factory(dead_addr, expect),
        ],
    );
    assert_eq!(router.alive_count(), 1, "dead slot must start down");

    let imgs = images(&sm, 4, 9);
    let pending: Vec<_> = (0..8)
        .map(|i| router.submit(&imgs[i % imgs.len()]).expect("submit"))
        .collect();
    for p in pending {
        p.recv().unwrap();
    }
    router.heal_now(); // reconnect attempt fails; slot stays empty
    assert_eq!(router.alive_count(), 1);
    assert_eq!(router.restarts(), 0, "a failed reconnect is not a restart");
    let fleet = router.shutdown();
    assert_eq!(fleet.fleet.requests, 8);
    assert_eq!(fleet.replicas[0].routed, 8);
    handle.shutdown();
}

// ---------------------------------------------------------------- //
// Backpressure under remote-shaped latency (slow-replica stubs)    //
// ---------------------------------------------------------------- //

/// A [`ReplicaBackend`] with an injected per-request service delay —
/// the latency shape of a remote worker, with none of the sockets.
struct SlowStub {
    alive: Arc<AtomicBool>,
    outstanding: Arc<AtomicUsize>,
    accepted: Arc<AtomicUsize>,
    acc: Arc<Mutex<RawServeStats>>,
    tx: Option<mpsc::Sender<(Vec<f32>, mpsc::Sender<Reply>, Instant)>>,
    worker: Option<thread::JoinHandle<()>>,
}

fn slow_stub(
    delay: Duration,
    outstanding: Arc<AtomicUsize>,
    accepted: Arc<AtomicUsize>,
) -> SlowStub {
    let alive = Arc::new(AtomicBool::new(true));
    let acc = Arc::new(Mutex::new(RawServeStats::default()));
    let (tx, rx) =
        mpsc::channel::<(Vec<f32>, mpsc::Sender<Reply>, Instant)>();
    let worker = {
        let outstanding = Arc::clone(&outstanding);
        let acc = Arc::clone(&acc);
        thread::spawn(move || {
            while let Ok((img, reply_tx, t0)) = rx.recv() {
                thread::sleep(delay);
                let latency = t0.elapsed();
                {
                    let mut a = acc.lock().unwrap();
                    a.images += 1;
                    a.latencies_ns.push(latency.as_nanos() as f64);
                    a.batch_sizes.push(1);
                    if a.first.is_none() {
                        a.first = Some(t0);
                    }
                    a.last = Some(Instant::now());
                }
                let _ = reply_tx.send(Reply {
                    pred: 0,
                    logits: vec![img.first().copied().unwrap_or(0.0)],
                    latency,
                    batch: 1,
                });
                outstanding.fetch_sub(1, Ordering::SeqCst);
            }
        })
    };
    SlowStub {
        alive,
        outstanding,
        accepted,
        acc,
        tx: Some(tx),
        worker: Some(worker),
    }
}

impl ReplicaBackend for SlowStub {
    fn try_submit(
        &self,
        image: Vec<f32>,
    ) -> std::result::Result<mpsc::Receiver<Reply>, Vec<f32>> {
        if !self.alive.load(Ordering::SeqCst) {
            return Err(image);
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.outstanding.fetch_add(1, Ordering::SeqCst);
        let sent = self
            .tx
            .as_ref()
            .expect("stub running")
            .send((image, reply_tx, Instant::now()));
        match sent {
            Ok(()) => {
                self.accepted.fetch_add(1, Ordering::SeqCst);
                Ok(reply_rx)
            }
            Err(mpsc::SendError((img, _, _))) => {
                self.outstanding.fetch_sub(1, Ordering::SeqCst);
                Err(img)
            }
        }
    }

    fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::SeqCst)
    }

    fn alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    fn kill(&self) {
        self.alive.store(false, Ordering::SeqCst);
    }

    fn drain_then_stop(mut self: Box<Self>) -> RawServeStats {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        let acc = self.acc.lock().unwrap();
        acc.clone()
    }
}

fn stub_factory(
    delay: Duration,
    accepted: Arc<AtomicUsize>,
) -> ReplicaFactory {
    Box::new(move |outstanding| {
        Ok(Box::new(slow_stub(
            delay,
            outstanding,
            Arc::clone(&accepted),
        )) as Box<dyn ReplicaBackend>)
    })
}

/// Satellite: backpressure under remote latency. A single slow replica
/// at queue cap C accepts exactly C requests; the C+1th submit surfaces
/// the typed `Overloaded` error at the ROUTER — the slow backend never
/// sees it — and capacity returns once replies drain.
#[test]
fn slow_replica_surfaces_overloaded_before_cap_exceeded() {
    let accepted = Arc::new(AtomicUsize::new(0));
    let router = Router::start_with_backends(
        RouterConfig {
            replicas: 1,
            policy: RoutingPolicy::LeastOutstanding,
            queue_cap: 4,
            health_every: Duration::ZERO,
            max_retries: 8,
            seed: 11,
            request_timeout: None,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            serve: serve_cfg(Duration::from_millis(1)),
        },
        8,
        vec![stub_factory(
            Duration::from_millis(200),
            Arc::clone(&accepted),
        )],
    );
    let img = vec![1.0f32; 8];
    let mut pending = Vec::new();
    for _ in 0..4 {
        pending.push(router.submit(&img).expect("under cap"));
    }
    assert_eq!(router.outstanding(), 4);
    match router.submit(&img) {
        Err(SubmitError::Overloaded { outstanding, cap }) => {
            assert_eq!(cap, 4);
            assert_eq!(outstanding, 4);
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    assert_eq!(
        accepted.load(Ordering::SeqCst),
        4,
        "the slow backend must never see the over-cap request"
    );
    for p in pending {
        p.recv().unwrap();
    }
    assert_eq!(router.outstanding(), 0);
    router.submit(&img).expect("capacity back after drain").recv().unwrap();
    let fleet = router.shutdown();
    assert_eq!(fleet.fleet.requests, 5);
    assert_eq!(fleet.rejected, 1, "exactly one typed rejection");
}

/// Satellite: power-of-two-choices reads the live outstanding gauges,
/// so a replica with remote-shaped latency accumulates load and the
/// policy steers traffic to the fast one instead of splitting evenly.
#[test]
fn p2c_steers_away_from_slow_replica() {
    let fast_accepted = Arc::new(AtomicUsize::new(0));
    let slow_accepted = Arc::new(AtomicUsize::new(0));
    let router = Router::start_with_backends(
        RouterConfig {
            replicas: 2,
            policy: RoutingPolicy::PowerOfTwo,
            queue_cap: 1024,
            health_every: Duration::ZERO,
            max_retries: 8,
            seed: 11,
            request_timeout: None,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            serve: serve_cfg(Duration::from_millis(1)),
        },
        8,
        vec![
            stub_factory(
                Duration::from_millis(1),
                Arc::clone(&fast_accepted),
            ),
            stub_factory(
                Duration::from_millis(40),
                Arc::clone(&slow_accepted),
            ),
        ],
    );
    let img = vec![1.0f32; 8];
    let mut pending = Vec::new();
    for _ in 0..80 {
        pending.push(router.submit(&img).expect("submit"));
        // paced submits: the fast replica drains between arrivals, the
        // slow one visibly queues — the signal p2c is built to read
        thread::sleep(Duration::from_millis(2));
    }
    for p in pending {
        p.recv().unwrap();
    }
    let fleet = router.shutdown();
    assert_eq!(fleet.fleet.requests, 80);
    let (fast, slow) =
        (fleet.replicas[0].routed, fleet.replicas[1].routed);
    assert_eq!(fast + slow, 80);
    assert!(
        fast > slow + 10,
        "p2c must steer away from the loaded replica \
         (fast {fast} vs slow {slow})"
    );
}

// ---------------------------------------------------------------- //
// Process-level tests: real `uniq serve --remote-worker` children   //
// ---------------------------------------------------------------- //

/// The model flags every worker/client process in these tests runs
/// with. `--width 2` maps to the mlp hidden width 32 that `model()`
/// builds in-process (the CLI scales mlp width by 16), so all three
/// views — this test, the worker process, the client process — freeze
/// the identical snapshot.
const MODEL_FLAGS: [&str; 9] = [
    "--synth", "--model", "mlp", "--width", "2", "--classes", "10",
    "--seed", "7",
];

fn worker_args() -> Vec<String> {
    let mut args: Vec<String> = vec![
        "serve".into(),
        "--remote-worker".into(),
        "127.0.0.1:0".into(),
        "--workers".into(),
        "1".into(),
        "--max-batch".into(),
        "16".into(),
        "--max-wait-ms".into(),
        "1".into(),
    ];
    args.extend(MODEL_FLAGS.iter().map(|f| f.to_string()));
    args
}

/// Spawn a real worker process and parse its banner for the ephemeral
/// address. Stdout keeps draining on a thread so the child never
/// blocks on a full pipe.
fn spawn_worker_process() -> (Child, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_uniq"))
        .args(worker_args())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .stdin(Stdio::null())
        .spawn()
        .expect("spawn worker process");
    let stdout = child.stdout.take().expect("child stdout piped");
    let mut lines = BufReader::new(stdout).lines();
    let addr = loop {
        let line = lines
            .next()
            .expect("worker exited before printing its banner")
            .expect("read worker stdout");
        if line.contains("remote-worker listening on") {
            break line.split_whitespace().last().unwrap().to_string();
        }
    };
    thread::spawn(move || for _ in lines {});
    (child, addr)
}

/// A real child process serving the frame protocol: connect, verify
/// the Hello, round-trip traffic, and pin the replies bit-identical
/// to this process's own forward of the same frozen snapshot — the
/// cross-process determinism the fleet is built on.
#[test]
fn worker_process_roundtrip_bit_identical() {
    let sm = model();
    let (mut child, addr) = spawn_worker_process();
    let replica = RemoteReplica::connect(
        &addr,
        Some(expect_of(&sm)),
        RemoteOpts::default(),
        Arc::new(AtomicUsize::new(0)),
    )
    .expect("connect to worker process");
    assert!(replica.hello().model.contains("mlp"));

    let imgs = images(&sm, 8, 5);
    let pending: Vec<_> = imgs
        .iter()
        .map(|img| {
            submit_blocking(&replica, img.clone(), Duration::from_secs(5))
                .expect("submit")
        })
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let reply = rx.recv().unwrap();
        let want = sm
            .graph
            .forward(&sm.model, &sm.weights, &imgs[i], 1, KernelMode::Lut)
            .unwrap();
        assert_eq!(
            reply.logits, want,
            "request {i}: cross-process logits drifted"
        );
    }
    let stats = replica.drain_then_stop();
    assert_eq!(stats.images, 8);
    let _ = child.kill();
    let _ = child.wait();
}

/// The acceptance round-trip, both halves as real processes: a worker
/// child serves `--remote-worker`, a client child drives
/// `serve --remote HOST:PORT` over loopback and must exit cleanly
/// with its fleet report.
#[test]
fn cli_client_roundtrips_against_worker_process() {
    let (mut worker, addr) = spawn_worker_process();
    let mut args: Vec<String> = vec![
        "serve".into(),
        "--remote".into(),
        addr,
        "--requests".into(),
        "48".into(),
        "--max-wait-ms".into(),
        "1".into(),
    ];
    args.extend(MODEL_FLAGS.iter().map(|f| f.to_string()));
    let out = Command::new(env!("CARGO_BIN_EXE_uniq"))
        .args(&args)
        .stdin(Stdio::null())
        .output()
        .expect("run client process");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "client process failed\nstdout:\n{stdout}\nstderr:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        stdout.contains("remote workers"),
        "client must report the remote fleet banner, got:\n{stdout}"
    );
    let _ = worker.kill();
    let _ = worker.wait();
}

/// The CI chaos soak: 600 requests across 2 spawned worker processes,
/// worker 1 SIGKILLed at the halfway submit with traffic in flight,
/// automatic (monitor-driven) respawn through the supervisor, zero
/// dropped requests, every reply bit-identical to this process's own
/// forward — the cross-process zero-drop guarantee, end to end.
#[test]
#[ignore = "soak: run explicitly (CI bench job) with -- soak --ignored"]
fn soak_sigkill_worker_process_mid_run_zero_drops() {
    let sm = model();
    let n = 600;
    let imgs = images(&sm, 48, 13);
    let expected: Vec<Vec<f32>> = imgs
        .iter()
        .map(|img| {
            sm.graph
                .forward(&sm.model, &sm.weights, img, 1, KernelMode::Lut)
                .unwrap()
        })
        .collect();

    let spec = WorkerSpec::Spawn {
        cmd: env!("CARGO_BIN_EXE_uniq").to_string(),
        args: worker_args(),
        banner_timeout: Duration::from_secs(30),
    };
    let sup = Supervisor::new(
        vec![spec.clone(), spec],
        ModelExpect {
            img_len: sm.image_len(),
            classes: sm.model.classes,
        },
        RemoteOpts::default(),
    );
    let router = Router::start_with_backends(
        RouterConfig {
            replicas: 2,
            policy: RoutingPolicy::PowerOfTwo,
            queue_cap: 8192,
            // the soak exercises the REAL supervision path: the
            // monitor must notice the SIGKILL and respawn the process
            health_every: Duration::from_millis(3),
            max_retries: 8,
            seed: 29,
            request_timeout: None,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            serve: serve_cfg(Duration::from_millis(1)),
        },
        sm.image_len(),
        sup.factories(),
    );

    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        if i == n / 2 {
            assert!(sup.kill_worker(1), "no child process to SIGKILL");
        }
        let img = &imgs[i % imgs.len()];
        let p = loop {
            match router.submit(img) {
                Ok(p) => break p,
                // transient while the kill propagates: retry, the
                // zero-drop contract is on replies, not first tries
                Err(SubmitError::Overloaded { .. })
                | Err(SubmitError::NoReplica) => {
                    thread::sleep(Duration::from_micros(500));
                }
                Err(e) => panic!("submit failed terminally: {e:?}"),
            }
        };
        pending.push(p);
    }
    for (i, p) in pending.into_iter().enumerate() {
        let reply = p.recv().unwrap_or_else(|e| {
            panic!("request {i} dropped across the SIGKILL: {e}")
        });
        assert_eq!(
            reply.logits,
            expected[i % imgs.len()],
            "request {i}: fleet output differs from direct forward"
        );
    }
    let fleet = router.shutdown();
    assert_eq!(
        fleet.fleet.requests, n,
        "every request must be served exactly once across the kill"
    );
    assert!(
        fleet.restarts >= 1,
        "the monitor never respawned the killed worker"
    );
    assert!(
        sup.spawn_count() >= 3,
        "2 initial spawns + at least one respawn, got {}",
        sup.spawn_count()
    );
    println!(
        "remote soak: {} requests, {} spawns, {} restarts, {} resubmits, \
         {} lost in flight — zero drops, bit-identical",
        n,
        sup.spawn_count(),
        fleet.restarts,
        fleet.resubmits,
        fleet.lost_in_flight
    );
    sup.shutdown();
}

// ---------------------------------------------------------------- //
// Liveness layer: heartbeats, deadlines, breaker, chaos plans      //
// ---------------------------------------------------------------- //

/// A worker whose `--shed-after-ms` budget is already blown sheds the
/// request at batch time and the client surfaces it as the SAME typed
/// `DeadlineExceeded` a local expiry produces — worker-side sheds are
/// accounted (fleet counter, liveness ledger, breaker), never silent.
#[test]
fn worker_side_shed_surfaces_typed_deadline() {
    let sm = model();
    let mut cfg = serve_cfg(Duration::from_millis(1));
    cfg.shed_after = Some(Duration::ZERO);
    let worker =
        Worker::bind(Arc::clone(&sm), cfg, "127.0.0.1:0").unwrap();
    let addr = worker.addr().to_string();
    let handle = worker.spawn();

    let expect = expect_of(&sm);
    let router = Router::start_with_backends(
        RouterConfig {
            replicas: 1,
            policy: RoutingPolicy::RoundRobin,
            queue_cap: 1024,
            health_every: Duration::ZERO,
            max_retries: 8,
            seed: 11,
            request_timeout: None,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            serve: serve_cfg(Duration::from_millis(1)),
        },
        sm.image_len(),
        vec![connect_factory(addr, expect)],
    );

    let imgs = images(&sm, 3, 17);
    for (i, img) in imgs.iter().enumerate() {
        match router.submit(img).unwrap().recv() {
            Err(SubmitError::DeadlineExceeded { .. }) => {}
            other => panic!(
                "request {i}: want DeadlineExceeded from the \
                 worker-side shed, got {other:?}"
            ),
        }
    }
    let fleet = router.shutdown();
    assert_eq!(fleet.deadline_expired, 3, "every shed counted");
    assert_eq!(
        fleet.liveness.deadline_reaped, 3,
        "the client reader must count worker-shed notices"
    );
    assert_eq!(
        fleet.breaker_trips, 1,
        "3 consecutive expiries on one slot trip its breaker once"
    );
    assert_eq!(fleet.fleet.requests, 0, "no request was ever served");
    handle.shutdown();
}

/// A wedged-but-connected worker: the chaos plan freezes the pump on
/// its first item (the first Pong), so the TCP connection stays open
/// while replies and pongs starve. The heartbeat cycle must declare
/// the stall within a few windows — the failure mode DESIGN §12's old
/// "no steady-state read deadline" rule could never catch.
#[test]
fn heartbeat_detects_frozen_pump() {
    let sm = model();
    let worker = Worker::bind_with(
        Arc::clone(&sm),
        serve_cfg(Duration::from_millis(1)),
        "127.0.0.1:0",
        Some(FaultPlan::parse("freeze:0").unwrap()),
    )
    .unwrap();
    let addr = worker.addr().to_string();
    let handle = worker.spawn();

    let replica = RemoteReplica::connect(
        &addr,
        Some(expect_of(&sm)),
        RemoteOpts {
            heartbeat_every: Some(Duration::from_millis(10)),
            heartbeat_misses: 3,
            ..RemoteOpts::default()
        },
        Arc::new(AtomicUsize::new(0)),
    )
    .unwrap();
    assert!(replica.alive(), "handshake precedes the frozen pump");

    let t0 = Instant::now();
    while replica.alive() && t0.elapsed() < Duration::from_secs(10) {
        thread::sleep(Duration::from_millis(2));
    }
    assert!(
        !replica.alive(),
        "a frozen pump must be declared stalled by missed heartbeats"
    );
    let live = replica.liveness();
    assert_eq!(live.hb_stalls, 1, "exactly one stall verdict");
    assert_eq!(live.pongs, 0, "the frozen pump never ponged");
    handle.shutdown();
}

/// A Pong whose id was never sent (a confused or malicious peer) is
/// counted and logged — it neither crashes the reader nor counts as a
/// solicited liveness proof. Regression test for the reader's old
/// silent `FrameKind::Pong => {}` discard.
#[test]
fn unexpected_pong_is_counted_not_fatal() {
    let sm = model();
    let (img_len, classes) = expect_of(&sm);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let srv = thread::spawn(move || {
        let (mut conn, _) = listener.accept().unwrap();
        let hello = Hello {
            proto: PROTO_VERSION as u64,
            model: "mlp/fake".into(),
            img_len: img_len as u64,
            classes: classes as u64,
        };
        write_frame(&mut conn, FrameKind::Hello, 0, &hello.encode())
            .unwrap();
        // a pong nobody asked for
        write_frame(&mut conn, FrameKind::Pong, 42, &[]).unwrap();
        // hold the connection open until the client goes away
        let mut buf = [0u8; 64];
        loop {
            match std::io::Read::read(&mut conn, &mut buf) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    });

    let replica = RemoteReplica::connect(
        &addr,
        Some((img_len, classes)),
        RemoteOpts {
            heartbeat_every: None,
            ..RemoteOpts::default()
        },
        Arc::new(AtomicUsize::new(0)),
    )
    .unwrap();
    let t0 = Instant::now();
    while replica.liveness().unexpected_pongs == 0
        && t0.elapsed() < Duration::from_secs(5)
    {
        thread::sleep(Duration::from_millis(2));
    }
    let live = replica.liveness();
    assert_eq!(live.unexpected_pongs, 1, "the stray pong is counted");
    assert_eq!(live.pongs, 0, "it is NOT a solicited pong");
    assert!(replica.alive(), "a stray pong is logged, not fatal");
    drop(replica);
    let _ = srv.join();
}

/// `WorkerSpec::Spawn` carries its banner deadline: a worker that
/// never prints its banner fails the factory in the configured window,
/// not the 30 s production default.
#[test]
fn banner_timeout_is_configurable_and_fast() {
    let spec = WorkerSpec::Spawn {
        cmd: "/bin/sleep".into(),
        args: vec!["5".into()],
        banner_timeout: Duration::from_millis(150),
    };
    let sup = Supervisor::new(
        vec![spec],
        ModelExpect { img_len: 8, classes: 2 },
        RemoteOpts::default(),
    );
    let t0 = Instant::now();
    let err = sup.factories()[0](Arc::new(AtomicUsize::new(0)))
        .expect_err("/bin/sleep never prints a worker banner");
    assert!(
        t0.elapsed() < Duration::from_secs(3),
        "the 150 ms banner timeout must not cost the 30 s default \
         (took {:?})",
        t0.elapsed()
    );
    assert!(
        format!("{err:#}").contains("banner"),
        "the error must name the banner wait: {err:#}"
    );
    sup.shutdown();
}

/// The deterministic chaos matrix: one of two spawned workers carries
/// a scripted `--fault-plan` (never a kill — the process stays up and
/// misbehaves) while 400 requests flow. Every cell must end with zero
/// dropped requests and bit-identical replies; the stall-shaped cells
/// must additionally show the stall detected via missed heartbeats and
/// the slot breaker tripped. Parameterized by env so CI fans the same
/// test across its fault × routing matrix:
///
///   UNIQ_CHAOS_FAULT   stall | corrupt | delay | freeze  (default freeze)
///   UNIQ_CHAOS_ROUTING rr | p2c                          (default rr)
#[test]
#[ignore = "chaos: run explicitly (CI chaos-matrix job) with -- chaos --ignored"]
fn chaos_fault_plan_zero_drops_bit_identical() {
    let fault = std::env::var("UNIQ_CHAOS_FAULT")
        .unwrap_or_else(|_| "freeze".into());
    let plan = match fault.as_str() {
        // wedge the pump after 60 frames: heartbeats must catch it
        "freeze" => "freeze:60",
        // one 8 s write stall: starves replies AND pongs
        "stall" => "stall:60:8000",
        // one corrupted CRC: typed reader death, resubmit ledger
        "corrupt" => "corrupt:40",
        // every 3rd frame +20 ms: pure latency, nothing may die
        "delay" => "delay:3:20",
        other => panic!("unknown UNIQ_CHAOS_FAULT '{other}'"),
    };
    let routing = std::env::var("UNIQ_CHAOS_ROUTING")
        .unwrap_or_else(|_| "rr".into());
    let policy = match routing.as_str() {
        "rr" => RoutingPolicy::RoundRobin,
        "p2c" => RoutingPolicy::PowerOfTwo,
        other => panic!("unknown UNIQ_CHAOS_ROUTING '{other}'"),
    };

    let sm = model();
    let n = 400;
    let imgs = images(&sm, 48, 23);
    let expected: Vec<Vec<f32>> = imgs
        .iter()
        .map(|img| {
            sm.graph
                .forward(&sm.model, &sm.weights, img, 1, KernelMode::Lut)
                .unwrap()
        })
        .collect();

    let healthy = WorkerSpec::Spawn {
        cmd: env!("CARGO_BIN_EXE_uniq").to_string(),
        args: worker_args(),
        banner_timeout: Duration::from_secs(30),
    };
    let mut chaos_args = worker_args();
    chaos_args.extend(["--fault-plan".to_string(), plan.to_string()]);
    let chaotic = WorkerSpec::Spawn {
        cmd: env!("CARGO_BIN_EXE_uniq").to_string(),
        args: chaos_args,
        banner_timeout: Duration::from_secs(30),
    };
    let opts = RemoteOpts {
        heartbeat_every: Some(Duration::from_millis(25)),
        heartbeat_misses: 4,
        request_timeout: Some(Duration::from_secs(2)),
        ..RemoteOpts::default()
    };
    let sup = Supervisor::new(
        vec![healthy, chaotic],
        ModelExpect {
            img_len: sm.image_len(),
            classes: sm.model.classes,
        },
        opts.clone(),
    );
    let router = Router::start_with_backends(
        RouterConfig {
            replicas: 2,
            policy,
            queue_cap: 8192,
            health_every: Duration::from_millis(3),
            max_retries: 8,
            seed: 29,
            request_timeout: opts.request_timeout,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(100),
            serve: serve_cfg(Duration::from_millis(1)),
        },
        sm.image_len(),
        sup.factories(),
    );

    // Bounded in-flight window; a deadline expiry is an accounted
    // outcome, not a drop — the image goes back in the queue until it
    // is served, and every served reply must be bit-identical.
    fn settle(
        i: usize,
        p: Pending,
        expected: &[Vec<f32>],
        served: &mut usize,
        expired: &mut usize,
        retry: &mut VecDeque<usize>,
    ) {
        match p.recv() {
            Ok(reply) => {
                assert_eq!(
                    reply.logits,
                    expected[i % expected.len()],
                    "request {i}: fleet output differs from direct \
                     forward"
                );
                *served += 1;
            }
            Err(SubmitError::DeadlineExceeded { .. }) => {
                *expired += 1;
                retry.push_back(i);
            }
            Err(e) => panic!("request {i} dropped: {e}"),
        }
    }

    let mut queue: VecDeque<usize> = (0..n).collect();
    let mut pending: VecDeque<(usize, Pending)> = VecDeque::new();
    let (mut served, mut expired) = (0usize, 0usize);
    while let Some(i) = queue.pop_front() {
        assert!(
            expired <= 4 * n,
            "deadline expiries diverge: the fleet never recovered"
        );
        let img = &imgs[i % imgs.len()];
        loop {
            match router.submit(img) {
                Ok(p) => {
                    pending.push_back((i, p));
                    break;
                }
                // transient while the fault propagates (breaker open,
                // respawn in flight): drain one waiter, then retry
                Err(SubmitError::Overloaded { .. })
                | Err(SubmitError::NoReplica) => {
                    match pending.pop_front() {
                        Some((j, p)) => settle(
                            j,
                            p,
                            &expected,
                            &mut served,
                            &mut expired,
                            &mut queue,
                        ),
                        None => {
                            thread::sleep(Duration::from_micros(500))
                        }
                    }
                }
                Err(e) => panic!("submit failed terminally: {e:?}"),
            }
        }
        if pending.len() >= 64 {
            let (j, p) = pending.pop_front().unwrap();
            settle(
                j,
                p,
                &expected,
                &mut served,
                &mut expired,
                &mut queue,
            );
        }
    }
    while let Some((j, p)) = pending.pop_front() {
        settle(j, p, &expected, &mut served, &mut expired, &mut queue);
        assert!(
            expired <= 4 * n,
            "deadline expiries diverge: the fleet never recovered"
        );
        while let Some(i) = queue.pop_front() {
            let img = &imgs[i % imgs.len()];
            loop {
                match router.submit(img) {
                    Ok(p) => {
                        pending.push_back((i, p));
                        break;
                    }
                    Err(SubmitError::Overloaded { .. })
                    | Err(SubmitError::NoReplica) => {
                        thread::sleep(Duration::from_micros(500))
                    }
                    Err(e) => panic!("submit failed terminally: {e:?}"),
                }
            }
        }
    }
    assert_eq!(served, n, "zero drops: every request must be answered");

    let fleet = router.shutdown();
    match fault.as_str() {
        "freeze" | "stall" => {
            assert!(
                fleet.liveness.hb_stalls >= 1,
                "a wedged pump must be detected via missed heartbeats"
            );
            assert!(
                fleet.breaker_trips >= 1,
                "a stall verdict must trip the slot's breaker"
            );
            assert!(
                fleet.resubmits >= 1,
                "in-flight traffic on the stalled slot must resubmit"
            );
        }
        "corrupt" => assert!(
            fleet.resubmits >= 1 || fleet.lost_in_flight >= 1,
            "a corrupted frame must kill the reader and fire the \
             resubmit ledger"
        ),
        _ => {}
    }
    // the acceptance surface: every liveness counter is visible in the
    // fleet stats JSON
    let stats = fleet.to_json().to_string();
    for key in [
        "deadline_expired",
        "breaker_trips",
        "resubmits",
        "hb_stalls",
        "deadline_reaped",
        "pongs",
    ] {
        assert!(stats.contains(key), "fleet JSON lost the {key} key");
    }
    println!(
        "chaos[{fault}/{routing}]: {n} served bit-identical, {expired} \
         deadline expiries (requeued), {} resubmits, {} breaker trips, \
         {} hb stalls, {} spawns",
        fleet.resubmits,
        fleet.breaker_trips,
        fleet.liveness.hb_stalls,
        sup.spawn_count(),
    );
    sup.shutdown();
}
